//! SpaDA kernel library — the paper's evaluated kernels as SpaDA source.
//!
//! Each kernel is an embedded `.spada` file parsed and instantiated on
//! demand; [`KernelSpec`] couples the source with its meta-parameters so
//! the harness, examples and tests share one entry point.

use crate::machine::{MachineConfig, MachineProgram};
use crate::passes::{Options, PassStats};
use crate::sem::{instantiate, Bindings};
use crate::spada::{parse_kernel, pretty, Kernel};
use anyhow::{anyhow, Context, Result};

pub const CHAIN_REDUCE: &str = include_str!("spada/chain_reduce.spada");
pub const BROADCAST: &str = include_str!("spada/broadcast.spada");
pub const TREE_REDUCE: &str = include_str!("spada/tree_reduce.spada");
pub const TWO_PHASE_REDUCE: &str = include_str!("spada/two_phase_reduce.spada");
pub const GEMV: &str = include_str!("spada/gemv.spada");
pub const GEMV_TREE: &str = include_str!("spada/gemv_tree.spada");

/// All named kernels in the library.
pub fn sources() -> Vec<(&'static str, &'static str)> {
    vec![
        ("chain_reduce", CHAIN_REDUCE),
        ("broadcast", BROADCAST),
        ("tree_reduce", TREE_REDUCE),
        ("two_phase_reduce", TWO_PHASE_REDUCE),
        ("gemv", GEMV),
        ("gemv_tree", GEMV_TREE),
    ]
}

pub fn source(name: &str) -> Result<&'static str> {
    sources()
        .into_iter()
        .find(|(n, _)| *n == name)
        .map(|(_, s)| s)
        .ok_or_else(|| anyhow!("unknown kernel {name}"))
}

/// Parse a library kernel.
pub fn parse(name: &str) -> Result<Kernel> {
    let src = source(name)?;
    parse_kernel(src).map_err(|e| anyhow!("{name}: {e}"))
}

/// SpaDA LoC of a library kernel (Table II metric).
pub fn spada_loc(name: &str) -> Result<usize> {
    Ok(pretty::count_loc(&parse(name)?))
}

/// Convenience: parse + instantiate + compile a kernel.
///
/// Unless [`Options::check`] is off, the compiled machine program is
/// verified by the static dataflow semantics checker
/// ([`crate::analysis::check`]) — routing correctness, data races,
/// deadlock freedom — before it is handed back ("verify, then lower").
pub fn compile(
    name: &str,
    binds: &[(&str, i64)],
    cfg: &MachineConfig,
    opts: &Options,
) -> Result<(MachineProgram, PassStats, usize)> {
    let kernel = parse(name)?;
    let bindings: Bindings = binds.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    let prog = instantiate(&kernel, &bindings).context(name.to_string())?;
    let compiled = crate::csl::compile(&prog, cfg, opts).map_err(|e| anyhow!("{name}: {e}"))?;
    let loc = compiled.csl_loc();
    let mut machine = compiled.machine;
    if opts.check {
        let report = crate::analysis::check(&machine, cfg);
        if report.has_errors() {
            return Err(anyhow!("{name}: static dataflow check failed\n{report}"));
        }
        // Record the verdict so the simulator's runtime-deadlock path
        // can cite the compile-time check instead of re-running the
        // whole analysis.
        machine.meta.insert("static_check".into(), "clean".into());
    }
    Ok((machine, compiled.stats, loc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sources_parse() {
        for (name, _) in sources() {
            parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn spada_loc_counts() {
        // Order-of-magnitude agreement with the paper's Table II SpaDA
        // column (broadcast 23, chain 91-ish for 2-D; ours are the 1-D /
        // parameterized forms).
        assert!(spada_loc("broadcast").unwrap() >= 15);
        assert!(spada_loc("chain_reduce").unwrap() >= 30);
    }
}

//! Lowering: SpaDA IR → machine program (paper §V-C/D/E).
//!
//! Per PE equivalence class, the lowerer
//! 1. lays out PE-local memory (with phase-lifetime overlay reuse and
//!    extern-field forwarding when copy elimination is on),
//! 2. transforms the class's compute statements into a *logical task
//!    graph*: asynchronous fabric DSD operations carry completion
//!    actions; `await` points become task boundaries wired through
//!    activate/unblock pairs (binary join trees reduce in-degree > 2,
//!    the paper's "virtual nodes"),
//! 3. vectorizes `foreach`/`map` loops into DSD operations by pattern
//!    matching (§V-D), with a per-wavelet data-task fallback,
//! 4. coarsens statements into tasks (task fusion) and maps logical
//!    tasks onto hardware task IDs (task-ID recycling via dispatch state
//!    machines) — both toggleable for the Fig. 9 ablations.

use crate::ir::core as ir;
use crate::machine::{
    DsdKind, DsdOp, DsdRef, Dtype, FieldAlloc, IoBinding, MachineConfig, MachineProgram, MOp,
    PeClass, PortMap, SExpr, TaskAction, TaskActionKind, TaskDef, TaskKind,
};
use crate::machine::program::{IoDir, SBinOp};
use crate::passes::{ClassRegion, ColorAllocation, Options, PassError, PassStats};
use crate::spada::ast::{ArgDir, BinOp, Expr, UnOp};
use std::collections::{BTreeMap, HashMap};

/// Registers 0..REG_CAP are allocatable for program variables; the upper
/// registers are reserved for the task-recycling machinery: SCRATCH_REG
/// snapshots the dispatch selector at task entry (a branch may set
/// another task's selector mid-body, which must not re-steer *this*
/// run), and each recycled hardware task ID gets its own state register
/// counting down from 31.
const REG_CAP: u8 = 24;
const SCRATCH_REG: u8 = 24;
const STATE_REG_TOP: u8 = 63;

/// Result of lowering.
pub struct LowerResult {
    pub program: MachineProgram,
    pub stats: PassStats,
}

type LResult<T> = Result<T, PassError>;

fn err<T>(msg: impl Into<String>) -> LResult<T> {
    Err(PassError(msg.into()))
}

/// Lower a (checkerboarded) program.
pub fn lower(
    prog: &ir::Program,
    classes: &[ClassRegion],
    alloc: &ColorAllocation,
    cfg: &MachineConfig,
    opts: &Options,
) -> LResult<LowerResult> {
    let mut machine = MachineProgram {
        name: prog.name.clone(),
        routes: alloc.routes.clone(),
        colors_used: alloc.colors_used.clone(),
        ..Default::default()
    };
    let mut stats = PassStats::default();
    let mut io: Vec<IoBinding> = vec![];

    for region in classes {
        let mut cl = ClassLowerer::new(prog, region, alloc, cfg, opts);
        let pe_class = cl.run()?;
        stats.logical_tasks += cl.logical_task_count;
        stats.copies_eliminated += cl.copies_eliminated;
        stats.mem_bytes_max = stats.mem_bytes_max.max(pe_class.mem_size);
        stats.hw_task_ids = stats.hw_task_ids.max(
            pe_class
                .tasks
                .iter()
                .map(|t| t.hw_id)
                .collect::<std::collections::HashSet<_>>()
                .len(),
        );
        io.extend(cl.io_bindings);
        machine.classes.push(pe_class);
    }

    // Merge duplicate bindings and sanity-check agreement.
    io.sort_by(|a, b| (a.arg.clone(), format!("{:?}", a.subgrid)).cmp(&(b.arg.clone(), format!("{:?}", b.subgrid))));
    io.dedup_by(|a, b| a.arg == b.arg && a.subgrid == b.subgrid && a.dir == b.dir);
    for i in 0..io.len() {
        for j in (i + 1)..io.len() {
            if io[i].arg == io[j].arg
                && (io[i].elems_per_pe != io[j].elems_per_pe
                    || io[i].total_ports != io[j].total_ports)
            {
                return err(format!(
                    "arg {}: inconsistent I/O bindings across classes",
                    io[i].arg
                ));
            }
        }
    }
    machine.io = io;
    machine.meta.insert("kernel".into(), prog.name.clone());
    Ok(LowerResult { program: machine, stats })
}

// ---------------------------------------------------------------------
// Logical tasks
// ---------------------------------------------------------------------

#[derive(Debug)]
struct LTask {
    name: String,
    phase: usize,
    kind: LTaskKind,
    body: Vec<MOp>,
    /// Initially blocked (second join predecessor unblocks).
    blocked: bool,
    /// Number of times this task is a 2-predecessor join target (for
    /// re-block bookkeeping when recycled).
    two_pred_join: bool,
}

#[derive(Debug, PartialEq)]
enum LTaskKind {
    Local,
    Data { color: u8, wavelet_reg: u8 },
}

/// A dependency predecessor for a join point.
#[derive(Clone, Debug)]
enum Pred {
    /// End of a logical task's body.
    TaskEnd(usize),
    /// Completion of an async DSD op: (task, op index into body).
    AsyncOp(usize, usize),
}

/// An outstanding asynchronous completion.
#[derive(Clone, Debug)]
struct Pending {
    name: Option<String>,
    /// None = completes immediately (synchronous op).
    pred: Option<Pred>,
}

// ---------------------------------------------------------------------
// Per-class lowering
// ---------------------------------------------------------------------

struct ClassLowerer<'a> {
    prog: &'a ir::Program,
    region: &'a ClassRegion,
    alloc: &'a ColorAllocation,
    cfg: &'a MachineConfig,
    opts: &'a Options,

    // Memory layout
    field_addr: HashMap<String, u32>,
    field_len: HashMap<String, u32>,
    field_ty: HashMap<String, Dtype>,
    fields_out: Vec<FieldAlloc>,
    mem_size: u32,

    // Registers
    regs: HashMap<String, u8>,
    next_reg: u8,

    // Tasks
    tasks: Vec<LTask>,
    cur: usize,
    pending: Vec<Pending>,

    // Coord variable names of the block being lowered.
    coords: (String, String),

    // Outputs
    pub io_bindings: Vec<IoBinding>,
    pub logical_task_count: usize,
    pub copies_eliminated: usize,

    /// Arg aliases: arg name → field it is forwarded to (copy elim).
    in_alias: HashMap<String, String>,
    out_alias: HashMap<String, String>,
}

impl<'a> ClassLowerer<'a> {
    fn new(
        prog: &'a ir::Program,
        region: &'a ClassRegion,
        alloc: &'a ColorAllocation,
        cfg: &'a MachineConfig,
        opts: &'a Options,
    ) -> Self {
        ClassLowerer {
            prog,
            region,
            alloc,
            cfg,
            opts,
            field_addr: HashMap::new(),
            field_len: HashMap::new(),
            field_ty: HashMap::new(),
            fields_out: vec![],
            mem_size: 0,
            regs: HashMap::new(),
            next_reg: 0,
            tasks: vec![],
            cur: 0,
            pending: vec![],
            coords: ("i".into(), "j".into()),
            io_bindings: vec![],
            logical_task_count: 0,
            copies_eliminated: 0,
            in_alias: HashMap::new(),
            out_alias: HashMap::new(),
        }
    }

    fn run(&mut self) -> LResult<PeClass> {
        self.plan_aliases();
        self.layout_memory()?;

        // Group the class's blocks by phase.
        let mut by_phase: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (pi, bi) in &self.region.blocks {
            by_phase.entry(*pi).or_default().push(*bi);
        }

        if !by_phase.is_empty() {
            // Entry task.
            self.tasks.push(LTask {
                name: "entry".into(),
                phase: *by_phase.keys().next().unwrap(),
                kind: LTaskKind::Local,
                body: vec![],
                blocked: false,
                two_pred_join: false,
            });
            self.cur = 0;

            let phases: Vec<usize> = by_phase.keys().copied().collect();
            for &pi in &phases {
                for &bi in &by_phase[&pi] {
                    let block = &self.prog.phases[pi].computes[bi];
                    self.coords = block.coord_vars.clone();
                    let stmts = block.stmts.clone();
                    for s in &stmts {
                        self.lower_stmt(s, pi)?;
                        if !self.opts.fusion {
                            // Unfused: every statement ends its task.
                            self.break_task(vec![Pred::TaskEnd(self.cur)], pi, "step")?;
                        }
                    }
                }
                // Implicit awaitall at phase end.
                let mut preds = vec![Pred::TaskEnd(self.cur)];
                preds.extend(self.pending.drain(..).filter_map(|p| p.pred));
                self.break_task(preds, pi, "phase_end")?;
            }
            // Final task halts.
            self.tasks[self.cur].body.push(MOp::Halt);
            self.tasks[self.cur].name = "finish".into();
        }

        self.logical_task_count = self.tasks.len();
        let (task_defs, entry_hw) = self.assign_hw_ids()?;
        let entry_tasks = entry_hw.into_iter().collect();

        Ok(PeClass {
            name: self.region.name.clone(),
            subgrids: self.region.subgrids.clone(),
            fields: self.fields_out.clone(),
            mem_size: self.mem_size,
            tasks: task_defs,
            entry_tasks,
        })
    }

    // ------------------------------------------------------------------
    // Copy elimination planning (paper §V-E)
    // ------------------------------------------------------------------

    /// Decide which kernel-arg receives/sends can be forwarded directly
    /// to/from the target field (no staging copy).
    fn plan_aliases(&mut self) {
        if !self.opts.copy_elim {
            return;
        }
        let mut recv_counts: HashMap<(String, String), usize> = HashMap::new();
        let mut send_counts: HashMap<(String, String), usize> = HashMap::new();
        for (pi, bi) in &self.region.blocks {
            let block = &self.prog.phases[*pi].computes[*bi];
            scan_arg_io(&block.stmts, &mut recv_counts, &mut send_counts);
        }
        for ((arg, field), n) in recv_counts {
            if n == 1 && !field.is_empty() {
                self.in_alias.insert(arg, field);
                self.copies_eliminated += 1;
            }
        }
        for ((arg, field), n) in send_counts {
            if n == 1 && !field.is_empty() {
                self.out_alias.insert(arg, field);
                self.copies_eliminated += 1;
            }
        }
    }

    // ------------------------------------------------------------------
    // Memory layout (paper §V-E)
    // ------------------------------------------------------------------

    fn layout_memory(&mut self) -> LResult<()> {
        let mut cursor: u32 = 0;
        let alloc_field = |cur: &mut u32,
                               out: &mut Vec<FieldAlloc>,
                               addr_map: &mut HashMap<String, u32>,
                               len_map: &mut HashMap<String, u32>,
                               ty_map: &mut HashMap<String, Dtype>,
                               name: &str,
                               len: u32,
                               ty: Dtype,
                               is_extern: bool,
                               at: Option<u32>|
         -> u32 {
            let addr = at.unwrap_or(*cur);
            if at.is_none() {
                *cur += len * ty.size() as u32;
                // keep 4-byte alignment
                *cur = (*cur + 3) & !3;
            }
            out.push(FieldAlloc { name: name.into(), addr, len, ty, is_extern });
            addr_map.insert(name.into(), addr);
            len_map.insert(name.into(), len);
            ty_map.insert(name.into(), ty);
            addr
        };

        // Kernel-lifetime fields first.
        let mut phase_fields: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &fi in &self.region.fields {
            let f = &self.prog.fields[fi];
            match f.phase {
                None => {
                    let ext = self.is_aliased_field(&f.name);
                    alloc_field(
                        &mut cursor,
                        &mut self.fields_out,
                        &mut self.field_addr,
                        &mut self.field_len,
                        &mut self.field_ty,
                        &f.name,
                        f.elems() as u32,
                        f.ty,
                        ext,
                        None,
                    );
                }
                Some(p) => phase_fields.entry(p).or_default().push(fi),
            }
        }
        // Phase-scoped fields: overlay when copy_elim (memory opt) is on.
        let overlay_base = cursor;
        let mut max_overlay = 0u32;
        for (_p, fis) in &phase_fields {
            let mut local = if self.opts.copy_elim { overlay_base } else { cursor };
            for &fi in fis {
                let f = &self.prog.fields[fi];
                let ext = self.is_aliased_field(&f.name);
                let bytes = f.elems() as u32 * f.ty.size() as u32;
                alloc_field(
                    &mut local,
                    &mut self.fields_out,
                    &mut self.field_addr,
                    &mut self.field_len,
                    &mut self.field_ty,
                    &f.name,
                    f.elems() as u32,
                    f.ty,
                    ext,
                    None,
                );
                let _ = bytes;
            }
            if self.opts.copy_elim {
                max_overlay = max_overlay.max(local - overlay_base);
            } else {
                cursor = local;
            }
        }
        if self.opts.copy_elim {
            cursor = overlay_base + max_overlay;
        }

        // Extern staging fields for non-aliased args (copy-elim off or
        // multi-use), discovered from statements.
        let mut recv_counts: HashMap<(String, String), usize> = HashMap::new();
        let mut send_counts: HashMap<(String, String), usize> = HashMap::new();
        for (pi, bi) in &self.region.blocks {
            let block = &self.prog.phases[*pi].computes[*bi];
            scan_arg_io(&block.stmts, &mut recv_counts, &mut send_counts);
        }
        for ((arg, field), _) in recv_counts.iter() {
            if self.in_alias.contains_key(arg) {
                continue;
            }
            let len = *self.field_len.get(field).unwrap_or(&1);
            let ty = *self.field_ty.get(field).unwrap_or(&Dtype::F32);
            let name = format!("__ext_in_{arg}");
            if !self.field_addr.contains_key(&name) {
                alloc_field(
                    &mut cursor,
                    &mut self.fields_out,
                    &mut self.field_addr,
                    &mut self.field_len,
                    &mut self.field_ty,
                    &name,
                    len,
                    ty,
                    true,
                    None,
                );
            }
        }
        for ((arg, field), _) in send_counts.iter() {
            if self.out_alias.contains_key(arg) {
                continue;
            }
            let len = *self.field_len.get(field).unwrap_or(&1);
            let ty = *self.field_ty.get(field).unwrap_or(&Dtype::F32);
            let name = format!("__ext_out_{arg}");
            if !self.field_addr.contains_key(&name) {
                alloc_field(
                    &mut cursor,
                    &mut self.fields_out,
                    &mut self.field_addr,
                    &mut self.field_len,
                    &mut self.field_ty,
                    &name,
                    len,
                    ty,
                    true,
                    None,
                );
            }
        }
        // Scalar args used by this class.
        for arg in &self.prog.args {
            if !arg.extents.is_empty() {
                continue;
            }
            let name = format!("__arg_{}", arg.name);
            alloc_field(
                &mut cursor,
                &mut self.fields_out,
                &mut self.field_addr,
                &mut self.field_len,
                &mut self.field_ty,
                &name,
                1,
                arg.elem_ty,
                true,
                None,
            );
            self.io_bindings.push(IoBinding {
                arg: arg.name.clone(),
                field: name,
                dir: IoDir::In,
                subgrid: self.region.subgrids[0].clone(),
                elems_per_pe: 1,
                total_ports: 1,
                port_map: PortMap::default(),
                ty: arg.elem_ty,
            });
        }

        // Mark aliased fields extern.
        self.mem_size = cursor.max(4);
        if self.mem_size as usize > self.cfg.mem_bytes {
            return err(format!(
                "OOM: class {} needs {} B of PE memory (limit {} B)",
                self.region.name, self.mem_size, self.cfg.mem_bytes
            ));
        }
        Ok(())
    }

    fn is_aliased_field(&self, field: &str) -> bool {
        self.in_alias.values().any(|f| f == field) || self.out_alias.values().any(|f| f == field)
    }

    // ------------------------------------------------------------------
    // Task building
    // ------------------------------------------------------------------

    fn new_task(&mut self, name: &str, phase: usize) -> usize {
        self.tasks.push(LTask {
            name: format!("{}_{}", name, self.tasks.len()),
            phase,
            kind: LTaskKind::Local,
            body: vec![],
            blocked: false,
            two_pred_join: false,
        });
        self.tasks.len() - 1
    }

    /// Attach a task-control action to a predecessor.
    fn attach(&mut self, pred: &Pred, action: TaskAction) {
        match pred {
            Pred::TaskEnd(t) => self.tasks[*t].body.push(MOp::Control(action)),
            Pred::AsyncOp(t, op) => {
                if let MOp::Dsd(d) = &mut self.tasks[*t].body[*op] {
                    d.on_complete.push(action);
                } else {
                    unreachable!("AsyncOp pred must point at a Dsd op");
                }
            }
        }
    }

    /// End the current task, creating a successor activated once all
    /// `preds` complete (binary join tree for in-degree > 2).
    ///
    /// Fusion: a boundary whose only predecessor is the current task's
    /// own fall-through needs no task switch at all — execution simply
    /// continues (this elides the per-phase wakeup overhead for classes
    /// with nothing pending, a large win for deep phase chains like the
    /// tree reduction's levels).
    fn break_task(&mut self, preds: Vec<Pred>, phase: usize, name: &str) -> LResult<usize> {
        if self.opts.fusion
            && preds.len() == 1
            && matches!(preds[0], Pred::TaskEnd(t) if t == self.cur)
        {
            return Ok(self.cur);
        }
        let next = self.new_task(name, phase);
        self.wire_join(preds, next, phase);
        self.cur = next;
        Ok(next)
    }

    fn wire_join(&mut self, mut preds: Vec<Pred>, target: usize, phase: usize) {
        // The target's hw id is patched in later; actions reference
        // logical task indices for now (task field holds the index).
        match preds.len() {
            0 => {
                // No predecessors: activate immediately from current task.
                let cur = self.cur;
                self.attach(&Pred::TaskEnd(cur), TaskAction::activate(target as u8));
            }
            1 => {
                let p = preds.pop().unwrap();
                self.attach(&p, TaskAction::activate(target as u8));
            }
            2 => {
                let p2 = preds.pop().unwrap();
                let p1 = preds.pop().unwrap();
                self.attach(&p1, TaskAction::activate(target as u8));
                self.attach(&p2, TaskAction::unblock(target as u8));
                self.tasks[target].blocked = true;
                self.tasks[target].two_pred_join = true;
            }
            _ => {
                // Binary join tree: join the first two into a virtual
                // task, then recurse.
                let p2 = preds.remove(1);
                let p1 = preds.remove(0);
                let v = self.new_task("join", phase);
                self.attach(&p1, TaskAction::activate(v as u8));
                self.attach(&p2, TaskAction::unblock(v as u8));
                self.tasks[v].blocked = true;
                self.tasks[v].two_pred_join = true;
                let mut rest = vec![Pred::TaskEnd(v)];
                rest.extend(preds);
                self.wire_join(rest, target, phase);
            }
        }
    }

    /// Register an async op as pending; if `awaited`, immediately join.
    fn finish_async(
        &mut self,
        pred: Option<Pred>,
        completion: Option<String>,
        awaited: bool,
        phase: usize,
    ) -> LResult<()> {
        if awaited {
            if let Some(p) = pred {
                let preds = vec![Pred::TaskEnd(self.cur), p];
                self.break_task(preds, phase, "await")?;
            }
            // Immediate ops need no break.
        } else {
            self.pending.push(Pending { name: completion, pred });
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Statement lowering
    // ------------------------------------------------------------------

    fn lower_stmt(&mut self, s: &ir::Stmt, phase: usize) -> LResult<()> {
        match s {
            ir::Stmt::Await { completion } => {
                let idx = self.pending.iter().position(|p| p.name.as_deref() == Some(completion));
                match idx {
                    None => {} // completion of a synchronous op: already done
                    Some(i) => {
                        let p = self.pending.remove(i);
                        if let Some(pred) = p.pred {
                            let preds = vec![Pred::TaskEnd(self.cur), pred];
                            self.break_task(preds, phase, "await")?;
                        }
                    }
                }
            }
            ir::Stmt::AwaitAll => {
                let mut preds = vec![Pred::TaskEnd(self.cur)];
                preds.extend(self.pending.drain(..).filter_map(|p| p.pred));
                if preds.len() > 1 {
                    self.break_task(preds, phase, "awaitall")?;
                }
            }
            ir::Stmt::Assign { lhs, rhs } => {
                let op = self.lower_assign(lhs, rhs)?;
                self.tasks[self.cur].body.push(op);
            }
            ir::Stmt::Let { ty, name, init } => {
                let reg = self.reg(name)?;
                let val = self.sexpr(init)?;
                let _ = ty;
                self.tasks[self.cur].body.push(MOp::SetReg { reg, val });
            }
            ir::Stmt::For { var, range, body } => {
                let op = self.lower_for(var, range, body)?;
                self.tasks[self.cur].body.push(op);
            }
            ir::Stmt::If { cond, then_body, else_body } => {
                let c = self.sexpr(cond)?;
                let t = self.lower_sync_block(then_body)?;
                let e = self.lower_sync_block(else_body)?;
                self.tasks[self.cur].body.push(MOp::If { cond: c, then_ops: t, else_ops: e });
            }
            ir::Stmt::Async { body, completion, awaited } => {
                if *awaited && completion.is_none() {
                    for st in body {
                        self.lower_stmt(st, phase)?;
                    }
                } else {
                    return err("general async blocks with completions are not supported");
                }
            }
            ir::Stmt::Send { data, stream, completion, awaited } => {
                let pred = self.lower_send(data, stream)?;
                self.finish_async(pred, completion.clone(), *awaited, phase)?;
            }
            ir::Stmt::Recv { dst, stream, completion, awaited } => {
                let pred = self.lower_recv(dst, stream)?;
                self.finish_async(pred, completion.clone(), *awaited, phase)?;
            }
            ir::Stmt::ForeachRecv { index, elem, len, stream, body, completion, awaited } => {
                let pred =
                    self.lower_foreach(index.as_deref(), elem, len.as_ref(), stream, body, phase)?;
                self.finish_async(pred, completion.clone(), *awaited, phase)?;
            }
            ir::Stmt::Map { vars, ranges, body, completion, awaited } => {
                let ops = self.lower_map(vars, ranges, body)?;
                self.tasks[self.cur].body.extend(ops);
                self.finish_async(None, completion.clone(), *awaited, phase)?;
            }
        }
        Ok(())
    }

    fn lower_sync_block(&mut self, body: &[ir::Stmt]) -> LResult<Vec<MOp>> {
        let mut out = vec![];
        for s in body {
            match s {
                ir::Stmt::Assign { lhs, rhs } => out.push(self.lower_assign(lhs, rhs)?),
                ir::Stmt::Let { name, init, .. } => {
                    let reg = self.reg(name)?;
                    let val = self.sexpr(init)?;
                    out.push(MOp::SetReg { reg, val });
                }
                ir::Stmt::For { var, range, body } => out.push(self.lower_for(var, range, body)?),
                ir::Stmt::If { cond, then_body, else_body } => {
                    let c = self.sexpr(cond)?;
                    let t = self.lower_sync_block(then_body)?;
                    let e = self.lower_sync_block(else_body)?;
                    out.push(MOp::If { cond: c, then_ops: t, else_ops: e });
                }
                ir::Stmt::Map { vars, ranges, body, .. } => {
                    out.extend(self.lower_map(vars, ranges, body)?)
                }
                other => {
                    return err(format!(
                        "asynchronous statement inside a synchronous context: {other:?}"
                    ))
                }
            }
        }
        Ok(out)
    }

    // --- sends / receives -------------------------------------------

    fn stream_color(&self, id: usize) -> LResult<u8> {
        self.alloc
            .stream_color
            .get(&id)
            .copied()
            .ok_or_else(|| PassError(format!("stream {id} has no color (unused?)")))
    }

    /// Resolve a data expression to a memory vector: (field, offset, len).
    fn vec_of_expr(&mut self, e: &Expr) -> LResult<(String, SExpr, SExpr)> {
        match e {
            Expr::Ident(name) => {
                let len = *self
                    .field_len
                    .get(name)
                    .ok_or_else(|| PassError(format!("unknown field {name}")))?;
                Ok((name.clone(), SExpr::imm(0), SExpr::imm(len as i64)))
            }
            Expr::Index(base, idx) => {
                let Expr::Ident(name) = base.as_ref() else {
                    return err(format!("cannot send {e:?}"));
                };
                if idx.len() != 1 {
                    return err("multi-dimensional send slices are not supported");
                }
                let off = self.sexpr(&idx[0])?;
                Ok((name.clone(), off, SExpr::imm(1)))
            }
            other => err(format!("cannot send expression {other:?}")),
        }
    }

    fn mem_ref(&self, field: &str, offset: SExpr, len: SExpr) -> LResult<DsdRef> {
        let base = *self
            .field_addr
            .get(field)
            .ok_or_else(|| PassError(format!("field {field} not allocated on this class")))?;
        let ty = self.field_ty[field];
        Ok(DsdRef::Mem { base, offset, stride: 1, len, ty })
    }

    fn lower_send(&mut self, data: &Expr, stream: &ir::StreamRef) -> LResult<Option<Pred>> {
        match stream {
            ir::StreamRef::Local(id) => {
                let color = self.stream_color(*id)?;
                let (field, off, len) = self.vec_of_expr(data)?;
                let src = self.mem_ref(&field, off, len.clone())?;
                let ty = src.ty();
                let op = DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len, ty },
                    src0: Some(src),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                };
                self.tasks[self.cur].body.push(MOp::Dsd(op));
                Ok(Some(Pred::AsyncOp(self.cur, self.tasks[self.cur].body.len() - 1)))
            }
            ir::StreamRef::Arg { name, index } => {
                let (field, off, len) = self.vec_of_expr(data)?;
                self.record_io(name, &field, IoDir::Out, index)?;
                if self.out_alias.get(name).map(|f| f == &field).unwrap_or(false) {
                    // Forwarded: the field itself is the output buffer.
                    Ok(None)
                } else {
                    let staging = format!("__ext_out_{name}");
                    let dst = self.mem_ref(&staging, SExpr::imm(0), len.clone())?;
                    let src = self.mem_ref(&field, off, len)?;
                    let op = DsdOp {
                        kind: DsdKind::Mov,
                        dst,
                        src0: Some(src),
                        src1: None,
                        scalar: None,
                        is_async: false,
                        on_complete: vec![],
                    };
                    self.tasks[self.cur].body.push(MOp::Dsd(op));
                    Ok(None)
                }
            }
        }
    }

    fn lower_recv(&mut self, dst: &Expr, stream: &ir::StreamRef) -> LResult<Option<Pred>> {
        match stream {
            ir::StreamRef::Local(id) => {
                let color = self.stream_color(*id)?;
                let (field, off, len) = self.vec_of_expr(dst)?;
                let d = self.mem_ref(&field, off, len.clone())?;
                let ty = d.ty();
                let op = DsdOp {
                    kind: DsdKind::Mov,
                    dst: d,
                    src0: Some(DsdRef::FabIn { color, len, ty }),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                };
                self.tasks[self.cur].body.push(MOp::Dsd(op));
                Ok(Some(Pred::AsyncOp(self.cur, self.tasks[self.cur].body.len() - 1)))
            }
            ir::StreamRef::Arg { name, index } => {
                let (field, off, len) = self.vec_of_expr(dst)?;
                self.record_io(name, &field, IoDir::In, index)?;
                if self.in_alias.get(name).map(|f| f == &field).unwrap_or(false) {
                    Ok(None) // preloaded directly into the field
                } else {
                    let staging = format!("__ext_in_{name}");
                    let src = self.mem_ref(&staging, SExpr::imm(0), len.clone())?;
                    let d = self.mem_ref(&field, off, len)?;
                    let op = DsdOp {
                        kind: DsdKind::Mov,
                        dst: d,
                        src0: Some(src),
                        src1: None,
                        scalar: None,
                        is_async: false,
                        on_complete: vec![],
                    };
                    self.tasks[self.cur].body.push(MOp::Dsd(op));
                    Ok(None)
                }
            }
        }
    }

    /// Record an I/O binding for a kernel-arg access on this class.
    fn record_io(
        &mut self,
        arg: &str,
        field: &str,
        dir: IoDir,
        index: &[Expr],
    ) -> LResult<()> {
        let decl = self
            .prog
            .arg(arg)
            .ok_or_else(|| PassError(format!("unknown kernel argument {arg}")))?;
        if matches!(dir, IoDir::In) && decl.dir != ArgDir::ReadOnly {
            return err(format!("receiving from writeonly argument {arg}"));
        }
        if matches!(dir, IoDir::Out) && decl.dir != ArgDir::WriteOnly {
            return err(format!("sending to readonly argument {arg}"));
        }
        // Port map from affine index expressions.
        let mut pm = PortMap::default();
        if !index.is_empty() {
            if index.len() != decl.extents.len() {
                return err(format!(
                    "arg {arg}: indexed with {} dims, declared {}",
                    index.len(),
                    decl.extents.len()
                ));
            }
            let mut stride = 1i64;
            // Row-major: last index varies fastest.
            for (d, ie) in index.iter().enumerate().rev() {
                let (ax, ay, c) = self.affine_coords(ie)?;
                pm.ax += ax * stride;
                pm.ay += ay * stride;
                pm.c += c * stride;
                stride *= decl.extents[d];
            }
        }
        let total_ports = decl.extents.iter().product::<i64>().max(1) as u32;
        let target_field = if matches!(dir, IoDir::In) {
            if self.in_alias.get(arg).map(|f| f == field).unwrap_or(false) {
                field.to_string()
            } else {
                format!("__ext_in_{arg}")
            }
        } else if self.out_alias.get(arg).map(|f| f == field).unwrap_or(false) {
            field.to_string()
        } else {
            format!("__ext_out_{arg}")
        };
        let elems_per_pe = *self.field_len.get(&target_field).unwrap_or(&1);
        let ty = *self.field_ty.get(&target_field).unwrap_or(&Dtype::F32);
        // One binding per class region subgrid.
        for g in &self.region.subgrids {
            self.io_bindings.push(IoBinding {
                arg: arg.to_string(),
                field: target_field.clone(),
                dir,
                subgrid: g.clone(),
                elems_per_pe,
                total_ports,
                port_map: pm,
                ty,
            });
        }
        Ok(())
    }

    /// Extract an affine form a·i + b·j + c over the coordinate vars.
    fn affine_coords(&self, e: &Expr) -> LResult<(i64, i64, i64)> {
        match e {
            Expr::Int(v) => Ok((0, 0, *v)),
            Expr::Ident(n) if *n == self.coords.0 => Ok((1, 0, 0)),
            Expr::Ident(n) if *n == self.coords.1 => Ok((0, 1, 0)),
            Expr::Bin(BinOp::Add, a, b) => {
                let (ax, ay, ac) = self.affine_coords(a)?;
                let (bx, by, bc) = self.affine_coords(b)?;
                Ok((ax + bx, ay + by, ac + bc))
            }
            Expr::Bin(BinOp::Sub, a, b) => {
                let (ax, ay, ac) = self.affine_coords(a)?;
                let (bx, by, bc) = self.affine_coords(b)?;
                Ok((ax - bx, ay - by, ac - bc))
            }
            Expr::Bin(BinOp::Mul, a, b) => {
                let (ax, ay, ac) = self.affine_coords(a)?;
                let (bx, by, bc) = self.affine_coords(b)?;
                if ax == 0 && ay == 0 {
                    Ok((ac * bx, ac * by, ac * bc))
                } else if bx == 0 && by == 0 {
                    Ok((ax * bc, ay * bc, ac * bc))
                } else {
                    err(format!("non-affine port index {e:?}"))
                }
            }
            Expr::Bin(BinOp::Div, a, b) => {
                // Affine / const only when it divides cleanly.
                let (ax, ay, ac) = self.affine_coords(a)?;
                let (bx, by, bc) = self.affine_coords(b)?;
                if bx == 0 && by == 0 && bc != 0 && ax % bc == 0 && ay % bc == 0 && ac % bc == 0 {
                    Ok((ax / bc, ay / bc, ac / bc))
                } else {
                    err(format!("non-affine port index {e:?}"))
                }
            }
            Expr::Unary(UnOp::Neg, a) => {
                let (ax, ay, ac) = self.affine_coords(a)?;
                Ok((-ax, -ay, -ac))
            }
            other => err(format!("non-affine port index {other:?}")),
        }
    }

    // --- foreach receive (paper §V-D vectorization) -------------------

    fn lower_foreach(
        &mut self,
        index: Option<&str>,
        elem: &str,
        len: Option<&Expr>,
        stream: &ir::StreamRef,
        body: &[ir::Stmt],
        phase: usize,
    ) -> LResult<Option<Pred>> {
        let ir::StreamRef::Local(id) = stream else {
            return err("foreach over kernel-arg streams is not supported");
        };
        let color = self.stream_color(*id)?;
        let Some(len) = len else {
            return self.lower_foreach_datatask(index, elem, None, color, body, phase);
        };
        let n = self.sexpr(len)?;

        // Pattern matching on the loop body.
        if let Some(pred) = self.try_vectorize_foreach(index, elem, &n, color, body)? {
            return Ok(Some(pred));
        }
        // Fallback: per-wavelet data task with count (tiered fallback of
        // §V-D).
        self.lower_foreach_datatask(index, elem, Some(n), color, body, phase)
    }

    /// Try to vectorize a foreach-receive body into fabric DSD op(s).
    fn try_vectorize_foreach(
        &mut self,
        index: Option<&str>,
        elem: &str,
        n: &SExpr,
        color: u8,
        body: &[ir::Stmt],
    ) -> LResult<Option<Pred>> {
        let Some(k) = index else { return Ok(None) };

        // Helper: f[k] pattern.
        let as_vec = |e: &Expr| -> Option<(String, i64)> {
            match e {
                Expr::Index(b, idx) if idx.len() == 1 => {
                    let Expr::Ident(f) = b.as_ref() else { return None };
                    match &idx[0] {
                        Expr::Ident(v) if v == k => Some((f.clone(), 0)),
                        Expr::Bin(BinOp::Add, a, c) => match (a.as_ref(), c.as_ref()) {
                            (Expr::Ident(v), Expr::Int(c)) if v == k => Some((f.clone(), *c)),
                            (Expr::Int(c), Expr::Ident(v)) if v == k => Some((f.clone(), *c)),
                            _ => None,
                        },
                        Expr::Bin(BinOp::Sub, a, c) => match (a.as_ref(), c.as_ref()) {
                            (Expr::Ident(v), Expr::Int(c)) if v == k => Some((f.clone(), -*c)),
                            _ => None,
                        },
                        _ => None,
                    }
                }
                _ => None,
            }
        };
        let is_elem = |e: &Expr| matches!(e, Expr::Ident(v) if v == elem);
        let is_scalar_field = |me: &Self, e: &Expr| -> Option<String> {
            match e {
                Expr::Ident(f) if me.field_len.get(f) == Some(&1) => Some(f.clone()),
                _ => None,
            }
        };

        let fabin = |ty: Dtype| DsdRef::FabIn { color, len: n.clone(), ty };

        // Single accumulate: a[k] = a[k] + x  /  a[k] = g[k] + x  /
        //                    a[k] = x
        if body.len() == 1 {
            if let ir::Stmt::Assign { lhs, rhs } = &body[0] {
                if let Some((dst_f, 0)) = as_vec(lhs) {
                    let ty = self.field_ty.get(&dst_f).copied().unwrap_or(Dtype::F32);
                    // a[k] = x
                    if is_elem(rhs) {
                        let d = self.mem_ref(&dst_f, SExpr::imm(0), n.clone())?;
                        return self.push_fab_op(DsdKind::Mov, d, Some(fabin(ty)), None, None);
                    }
                    // a[k] = g[k] ± x or x + g[k]
                    if let Expr::Bin(op, l, r) = rhs {
                        let (vec_side, kind, swapped) = match op {
                            BinOp::Add if is_elem(r) => (l, DsdKind::Fadd, false),
                            BinOp::Add if is_elem(l) => (r, DsdKind::Fadd, false),
                            BinOp::Sub if is_elem(r) => (l, DsdKind::Fsub, false),
                            BinOp::Mul if is_elem(r) => (l, DsdKind::Fmul, false),
                            BinOp::Mul if is_elem(l) => (r, DsdKind::Fmul, false),
                            _ => (l, DsdKind::Mov, true),
                        };
                        if !swapped {
                            if let Some((src_f, off)) = as_vec(vec_side) {
                                let d = self.mem_ref(&dst_f, SExpr::imm(0), n.clone())?;
                                let s0 = self.mem_ref(&src_f, SExpr::imm(off), n.clone())?;
                                return self.push_fab_op(kind, d, Some(s0), Some(fabin(ty)), None);
                            }
                        }
                    }
                }
                // Scalar reduction: s = s + x (stride-0 accumulate).
                if let Some(sf) = is_scalar_field(self, lhs) {
                    if let Expr::Bin(BinOp::Add, l, r) = rhs {
                        let ok = (matches!(l.as_ref(), Expr::Ident(v) if *v == sf) && is_elem(r))
                            || (matches!(r.as_ref(), Expr::Ident(v) if *v == sf) && is_elem(l));
                        if ok {
                            let base = self.field_addr[&sf];
                            let ty = self.field_ty[&sf];
                            let d = DsdRef::Mem {
                                base,
                                offset: SExpr::imm(0),
                                stride: 0,
                                len: n.clone(),
                                ty,
                            };
                            let s0 = DsdRef::Mem {
                                base,
                                offset: SExpr::imm(0),
                                stride: 0,
                                len: n.clone(),
                                ty,
                            };
                            return self.push_fab_op(DsdKind::Fadd, d, Some(s0), Some(fabin(ty)), None);
                        }
                    }
                }
            }
        }

        // Accumulate-and-forward: { a[k] = a[k] + x; send(a[k], s2) }
        if body.len() == 2 {
            if let (ir::Stmt::Assign { lhs, rhs }, ir::Stmt::Send { data, stream: s2, .. }) =
                (&body[0], &body[1])
            {
                let dst = as_vec(lhs);
                let sent = as_vec(data);
                if let (Some((a_f, 0)), Some((sent_f, 0))) = (&dst, &sent) {
                    if a_f == sent_f {
                        // rhs must be a[k] + x.
                        let rhs_ok = matches!(rhs, Expr::Bin(BinOp::Add, l, r)
                            if (as_vec(l).map(|(f, o)| f == *a_f && o == 0).unwrap_or(false) && is_elem(r))
                            || (as_vec(r).map(|(f, o)| f == *a_f && o == 0).unwrap_or(false) && is_elem(l)));
                        if rhs_ok {
                            let ir::StreamRef::Local(out_id) = s2 else {
                                return Ok(None);
                            };
                            let out_color = self.stream_color(*out_id)?;
                            let ty = self.field_ty.get(a_f).copied().unwrap_or(Dtype::F32);
                            let s0 = self.mem_ref(a_f, SExpr::imm(0), n.clone())?;
                            // Fused streaming form: out = a + in, written
                            // directly to the fabric (the accumulator is
                            // a staging buffer — dead afterwards).
                            let d = DsdRef::FabOut { color: out_color, len: n.clone(), ty };
                            return self.push_fab_op(DsdKind::Fadd, d, Some(s0), Some(fabin(ty)), None);
                        }
                    }
                }
            }
        }

        Ok(None)
    }

    fn push_fab_op(
        &mut self,
        kind: DsdKind,
        dst: DsdRef,
        src0: Option<DsdRef>,
        src1: Option<DsdRef>,
        scalar: Option<SExpr>,
    ) -> LResult<Option<Pred>> {
        let op = DsdOp { kind, dst, src0, src1, scalar, is_async: true, on_complete: vec![] };
        self.tasks[self.cur].body.push(MOp::Dsd(op));
        Ok(Some(Pred::AsyncOp(self.cur, self.tasks[self.cur].body.len() - 1)))
    }

    /// Per-wavelet data-task fallback: a data task bound to `color` runs
    /// the body once per wavelet; with a known count it blocks itself and
    /// activates a completion proxy after `n` wavelets.
    fn lower_foreach_datatask(
        &mut self,
        index: Option<&str>,
        elem: &str,
        n: Option<SExpr>,
        color: u8,
        body: &[ir::Stmt],
        phase: usize,
    ) -> LResult<Option<Pred>> {
        let elem_reg = self.reg(elem)?;
        let cnt_reg = self.reg(&format!("__cnt_c{color}_p{phase}"))?;
        let mut ops: Vec<MOp> = vec![];
        if let Some(k) = index {
            let k_reg = self.reg(k)?;
            ops.push(MOp::SetReg { reg: k_reg, val: SExpr::Reg(cnt_reg) });
        }
        ops.extend(self.lower_sync_block(body)?);
        ops.push(MOp::SetReg {
            reg: cnt_reg,
            val: SExpr::add(SExpr::Reg(cnt_reg), SExpr::imm(1)),
        });

        let dt = self.tasks.len();
        let proxy = if n.is_some() {
            let proxy = self.new_task("recv_done", phase);
            ops.push(MOp::If {
                cond: SExpr::bin(SBinOp::Ge, SExpr::Reg(cnt_reg), n.clone().unwrap()),
                then_ops: vec![
                    MOp::Control(TaskAction {
                        kind: TaskActionKind::Block,
                        task: dt as u8 + 1, // patched: data task index is dt+1 after proxy? fixed below
                        set_reg: None,
                    }),
                    MOp::Control(TaskAction::activate(proxy as u8)),
                ],
                else_ops: vec![],
            });
            Some(proxy)
        } else {
            None
        };
        // Create the data task itself (logical index).
        let dt_idx = self.tasks.len();
        self.tasks.push(LTask {
            name: format!("data_c{color}_{dt_idx}"),
            phase,
            kind: LTaskKind::Data { color, wavelet_reg: elem_reg },
            body: ops,
            blocked: false,
            two_pred_join: false,
        });
        // Patch the self-block target to the data task's own index.
        if proxy.is_some() {
            let body_len = self.tasks[dt_idx].body.len();
            if let MOp::If { then_ops, .. } = &mut self.tasks[dt_idx].body[body_len - 1] {
                if let MOp::Control(a) = &mut then_ops[0] {
                    a.task = dt_idx as u8;
                }
            }
        }
        Ok(proxy.map(|p| Pred::TaskEnd(p)))
    }

    // --- map / loops (paper §V-D) -------------------------------------

    fn lower_map(
        &mut self,
        vars: &[String],
        ranges: &[(Expr, Expr, Expr)],
        body: &[ir::Stmt],
    ) -> LResult<Vec<MOp>> {
        if vars.len() == 1 {
            if let Some(ops) = self.try_vectorize_map(&vars[0], &ranges[0], body)? {
                return Ok(ops);
            }
        }
        // Fallback: sequential loop nest (CSL @map-style callback has the
        // same per-element cost in the machine model).
        self.loop_nest(vars, ranges, body)
    }

    fn loop_nest(
        &mut self,
        vars: &[String],
        ranges: &[(Expr, Expr, Expr)],
        body: &[ir::Stmt],
    ) -> LResult<Vec<MOp>> {
        if vars.is_empty() {
            return self.lower_sync_block(body);
        }
        let reg = self.reg(&vars[0])?;
        let start = self.sexpr(&ranges[0].0)?;
        let stop = self.sexpr(&ranges[0].1)?;
        let step = self.sexpr(&ranges[0].2)?;
        let inner = self.loop_nest(&vars[1..], &ranges[1..], body)?;
        Ok(vec![MOp::For { reg, start, stop, step, body: inner }])
    }

    fn lower_for(
        &mut self,
        var: &str,
        range: &(Expr, Expr, Expr),
        body: &[ir::Stmt],
    ) -> LResult<MOp> {
        let reg = self.reg(var)?;
        let start = self.sexpr(&range.0)?;
        let stop = self.sexpr(&range.1)?;
        let step = self.sexpr(&range.2)?;
        let inner = self.lower_sync_block(body)?;
        Ok(MOp::For { reg, start, stop, step, body: inner })
    }

    /// Vectorize `map k in [0:N] { dst[k±c] = expr }` into DSD ops.
    fn try_vectorize_map(
        &mut self,
        k: &str,
        range: &(Expr, Expr, Expr),
        body: &[ir::Stmt],
    ) -> LResult<Option<Vec<MOp>>> {
        // Range must start at 0 with step 1 (offsets fold into DSDs).
        if range.0 != Expr::Int(0) || range.2 != Expr::Int(1) {
            return Ok(None);
        }
        let n = self.sexpr(&range.1)?;
        if body.len() != 1 {
            return Ok(None);
        }
        let ir::Stmt::Assign { lhs, rhs } = &body[0] else { return Ok(None) };
        let Some((dst_f, dst_off)) = self.as_vec_ref(k, lhs)? else { return Ok(None) };
        let dst = self.mem_ref(&dst_f, dst_off, n.clone())?;
        let mut ops = vec![];
        if self.compile_vec_expr(k, &dst, rhs, &n, &mut ops, true)? {
            Ok(Some(ops))
        } else {
            Ok(None)
        }
    }

    /// `f[k]` / `f[k + e]` / `f[k - e]` pattern, where `e` is k-free.
    /// Returns (field, element-offset expression).
    fn as_vec_ref(&mut self, k: &str, e: &Expr) -> LResult<Option<(String, SExpr)>> {
        let r = match e {
            Expr::Index(b, idx) if idx.len() == 1 => {
                let Expr::Ident(f) = b.as_ref() else { return Ok(None) };
                if !self.field_addr.contains_key(f) {
                    return Ok(None);
                }
                match &idx[0] {
                    Expr::Ident(v) if v == k => Some((f.clone(), SExpr::imm(0))),
                    Expr::Bin(BinOp::Add, a, c) => match (a.as_ref(), c.as_ref()) {
                        (Expr::Ident(v), off) if v == k && !contains_var(off, k) => {
                            Some((f.clone(), self.sexpr(off)?))
                        }
                        (off, Expr::Ident(v)) if v == k && !contains_var(off, k) => {
                            Some((f.clone(), self.sexpr(off)?))
                        }
                        _ => None,
                    },
                    Expr::Bin(BinOp::Sub, a, c) => match (a.as_ref(), c.as_ref()) {
                        (Expr::Ident(v), off) if v == k && !contains_var(off, k) => {
                            Some((f.clone(), SExpr::Neg(Box::new(self.sexpr(off)?))))
                        }
                        _ => None,
                    },
                    _ => None,
                }
            }
            _ => None,
        };
        Ok(r)
    }

    /// k-free scalar expression (compiled to an SExpr), if any.
    fn as_scalar_sexpr(&mut self, k: &str, e: &Expr) -> LResult<Option<SExpr>> {
        if contains_var(e, k) {
            return Ok(None);
        }
        // Field vectors used without an index are not scalars.
        if let Expr::Ident(name) = e {
            if self.field_len.get(name).map(|l| *l > 1).unwrap_or(false) {
                return Ok(None);
            }
        }
        Ok(Some(self.sexpr(e)?))
    }

    /// Compile `dst[:] (=|+=) expr` into a chain of DSD ops. `init`
    /// selects initialize (=) vs accumulate (+=) semantics.
    /// Returns false when the expression doesn't fit the DSD forms.
    fn compile_vec_expr(
        &mut self,
        k: &str,
        dst: &DsdRef,
        e: &Expr,
        n: &SExpr,
        ops: &mut Vec<MOp>,
        init: bool,
    ) -> LResult<bool> {
        let mk = |kind, src0, src1, scalar| {
            MOp::Dsd(DsdOp {
                kind,
                dst: dst.clone(),
                src0,
                src1,
                scalar,
                is_async: false,
                on_complete: vec![],
            })
        };
        // Sum decomposition: e1 + e2 → compile e1, accumulate e2.
        if let Expr::Bin(BinOp::Add, a, b) = e {
            if self.compile_vec_expr(k, dst, a, n, ops, init)? {
                return self.compile_vec_expr(k, dst, b, n, ops, false);
            }
            return Ok(false);
        }
        if let Expr::Bin(BinOp::Sub, a, b) = e {
            // e1 - e2 → compile e1, accumulate −1·e2.
            if self.compile_vec_expr(k, dst, a, n, ops, init)? {
                let neg = Expr::Unary(UnOp::Neg, b.clone());
                return self.compile_vec_expr(k, dst, &neg, n, ops, false);
            }
            return Ok(false);
        }

        // Term forms: v[k+off], scalar·v[k+off], v·w (elementwise), scalar.
        // `scalar` is any k-free expression (a literal, a kernel scalar
        // argument, or a loop-indexed element like x[c] — the CSL
        // @fmacs(y, y, A_col, x[c]) idiom).
        let term = self.vec_term(k, e)?;
        let Some((v, w, c)) = term else { return Ok(false) };
        let one = matches!(c, SExpr::ImmF(v) if v == 1.0);
        match (v, w, init) {
            // dst = scalar
            (None, None, true) => {
                ops.push(mk(DsdKind::Fill, None, None, Some(c)));
                Ok(true)
            }
            (None, None, false) => Ok(false), // dst += scalar: no DSD form
            // dst = v·w
            (Some((vf, vo)), Some((wf, wo)), true) => {
                let s0 = self.mem_ref(&vf, vo, n.clone())?;
                let s1 = self.mem_ref(&wf, wo, n.clone())?;
                ops.push(mk(DsdKind::Fmul, Some(s0), Some(s1), None));
                Ok(true)
            }
            // dst += v·w → Fmac with unit scalar.
            (Some((vf, vo)), Some((wf, wo)), false) => {
                if !one {
                    return Ok(false);
                }
                // dst += v[k]·w[k] has no single-DSD form unless one
                // operand aliases dst; reject (needs a temp).
                let _ = (vf, vo, wf, wo);
                Ok(false)
            }
            // dst = c·v
            (Some((vf, vo)), None, true) => {
                let s0 = self.mem_ref(&vf, vo, n.clone())?;
                if one {
                    ops.push(mk(DsdKind::Mov, Some(s0), None, None));
                } else {
                    ops.push(mk(DsdKind::Fscale, Some(s0), None, Some(c)));
                }
                Ok(true)
            }
            // dst += c·v  → Fmac(dst, dst, v, c)
            (Some((vf, vo)), None, false) => {
                let s1 = self.mem_ref(&vf, vo, n.clone())?;
                ops.push(mk(DsdKind::Fmac, Some(dst.clone()), Some(s1), Some(c)));
                Ok(true)
            }
            (None, Some(_), _) => unreachable!("term extractor never yields w without v"),
        }
    }

    /// Extract a single product term: (vector, optional second vector,
    /// scalar coefficient as SExpr).
    #[allow(clippy::type_complexity)]
    fn vec_term(
        &mut self,
        k: &str,
        e: &Expr,
    ) -> LResult<Option<(Option<(String, SExpr)>, Option<(String, SExpr)>, SExpr)>> {
        // plain vector
        if let Some(v) = self.as_vec_ref(k, e)? {
            return Ok(Some((Some(v), None, SExpr::ImmF(1.0))));
        }
        // negation: negate the scalar coefficient
        if let Expr::Unary(UnOp::Neg, a) = e {
            if let Some((v, w, c)) = self.vec_term(k, a)? {
                return Ok(Some((v, w, SExpr::Neg(Box::new(c)))));
            }
            return Ok(None);
        }
        if let Expr::Bin(BinOp::Mul, a, b) = e {
            if let Some(c) = self.as_scalar_sexpr(k, a)? {
                if let Some(v) = self.as_vec_ref(k, b)? {
                    return Ok(Some((Some(v), None, c)));
                }
                return Ok(None);
            }
            if let Some(c) = self.as_scalar_sexpr(k, b)? {
                if let Some(v) = self.as_vec_ref(k, a)? {
                    return Ok(Some((Some(v), None, c)));
                }
                return Ok(None);
            }
            if let (Some(v), Some(w)) = (self.as_vec_ref(k, a)?, self.as_vec_ref(k, b)?) {
                return Ok(Some((Some(v), Some(w), SExpr::ImmF(1.0))));
            }
            return Ok(None);
        }
        if let Some(c) = self.as_scalar_sexpr(k, e)? {
            return Ok(Some((None, None, c)));
        }
        Ok(None)
    }

    // --- scalar expressions -------------------------------------------

    fn reg(&mut self, name: &str) -> LResult<u8> {
        if let Some(r) = self.regs.get(name) {
            return Ok(*r);
        }
        if self.next_reg >= REG_CAP {
            return err(format!(
                "OOR: class {} needs more than {} scalar registers",
                self.region.name, REG_CAP
            ));
        }
        let r = self.next_reg;
        self.next_reg += 1;
        self.regs.insert(name.to_string(), r);
        Ok(r)
    }

    fn lower_assign(&mut self, lhs: &Expr, rhs: &Expr) -> LResult<MOp> {
        let val = self.sexpr(rhs)?;
        match lhs {
            Expr::Ident(name) => {
                if self.field_addr.contains_key(name) {
                    let ty = self.field_ty[name];
                    Ok(MOp::Store { addr: SExpr::imm(self.field_addr[name] as i64), ty, val })
                } else {
                    let reg = self.reg(name)?;
                    Ok(MOp::SetReg { reg, val })
                }
            }
            Expr::Index(base, idx) => {
                let Expr::Ident(f) = base.as_ref() else {
                    return err(format!("cannot assign to {lhs:?}"));
                };
                let addr = self.elem_addr(f, idx)?;
                let ty = self.field_ty[f];
                Ok(MOp::Store { addr, ty, val })
            }
            other => err(format!("invalid assignment target {other:?}")),
        }
    }

    /// Byte address of field element f[idx...] as an SExpr.
    fn elem_addr(&mut self, f: &str, idx: &[Expr]) -> LResult<SExpr> {
        let base = *self
            .field_addr
            .get(f)
            .ok_or_else(|| PassError(format!("unknown field {f}")))?;
        let ty = self.field_ty[f];
        // Row-major over the declared shape.
        let field = self
            .prog
            .field(f)
            .map(|fd| fd.shape.clone())
            .unwrap_or_else(|| vec![self.field_len[f] as i64]);
        if idx.len() != field.len().max(1) && !(idx.len() == 1 && field.is_empty()) {
            return err(format!("field {f}: indexed with {} dims, shape {:?}", idx.len(), field));
        }
        let mut flat = SExpr::imm(0);
        let mut stride = 1i64;
        for (d, ie) in idx.iter().enumerate().rev() {
            let i = self.sexpr(ie)?;
            flat = SExpr::add(flat, SExpr::mul(i, SExpr::imm(stride)));
            stride *= field.get(d).copied().unwrap_or(1);
        }
        Ok(SExpr::add(
            SExpr::imm(base as i64),
            SExpr::mul(flat, SExpr::imm(ty.size() as i64)),
        ))
    }

    fn sexpr(&mut self, e: &Expr) -> LResult<SExpr> {
        Ok(match e {
            Expr::Int(v) => SExpr::ImmI(*v),
            Expr::Float(v) => SExpr::ImmF(*v),
            Expr::Ident(name) => {
                if *name == self.coords.0 {
                    SExpr::CoordX
                } else if *name == self.coords.1 {
                    SExpr::CoordY
                } else if let Some(addr) = self.field_addr.get(name) {
                    SExpr::LoadMem {
                        addr: Box::new(SExpr::imm(*addr as i64)),
                        ty: self.field_ty[name],
                    }
                } else if let Some(arg) = self.prog.arg(name) {
                    if arg.extents.is_empty() {
                        let staged = format!("__arg_{name}");
                        let addr = *self.field_addr.get(&staged).ok_or_else(|| {
                            PassError(format!("scalar arg {name} not staged on this class"))
                        })?;
                        SExpr::LoadMem { addr: Box::new(SExpr::imm(addr as i64)), ty: arg.elem_ty }
                    } else {
                        return err(format!("stream argument {name} used as a value"));
                    }
                } else if let Some(r) = self.regs.get(name) {
                    SExpr::Reg(*r)
                } else {
                    // Forward reference to a loop/let variable.
                    SExpr::Reg(self.reg(name)?)
                }
            }
            Expr::Index(base, idx) => {
                let Expr::Ident(f) = base.as_ref() else {
                    return err(format!("cannot index {base:?}"));
                };
                let addr = self.elem_addr(f, idx)?;
                SExpr::LoadMem { addr: Box::new(addr), ty: self.field_ty[f] }
            }
            Expr::Unary(UnOp::Neg, a) => SExpr::Neg(Box::new(self.sexpr(a)?)),
            Expr::Unary(UnOp::Not, a) => SExpr::Not(Box::new(self.sexpr(a)?)),
            Expr::Bin(op, a, b) => {
                let sa = self.sexpr(a)?;
                let sb = self.sexpr(b)?;
                let so = match op {
                    BinOp::Add => SBinOp::Add,
                    BinOp::Sub => SBinOp::Sub,
                    BinOp::Mul => SBinOp::Mul,
                    BinOp::Div => SBinOp::Div,
                    BinOp::Mod => SBinOp::Mod,
                    BinOp::Eq => SBinOp::Eq,
                    BinOp::Ne => SBinOp::Ne,
                    BinOp::Lt => SBinOp::Lt,
                    BinOp::Le => SBinOp::Le,
                    BinOp::Gt => SBinOp::Gt,
                    BinOp::Ge => SBinOp::Ge,
                    BinOp::And => SBinOp::And,
                    BinOp::Or => SBinOp::Or,
                };
                SExpr::bin(so, sa, sb)
            }
            Expr::Cond { then, cond, els } => SExpr::Select(
                Box::new(self.sexpr(cond)?),
                Box::new(self.sexpr(then)?),
                Box::new(self.sexpr(els)?),
            ),
            Expr::Call(name, args) => match (name.as_str(), args.len()) {
                ("min", 2) => {
                    SExpr::bin(SBinOp::Min, self.sexpr(&args[0])?, self.sexpr(&args[1])?)
                }
                ("max", 2) => {
                    SExpr::bin(SBinOp::Max, self.sexpr(&args[0])?, self.sexpr(&args[1])?)
                }
                ("abs", 1) => {
                    let a = self.sexpr(&args[0])?;
                    SExpr::Select(
                        Box::new(SExpr::bin(SBinOp::Ge, a.clone(), SExpr::imm(0))),
                        Box::new(a.clone()),
                        Box::new(SExpr::Neg(Box::new(a))),
                    )
                }
                _ => return err(format!("unknown builtin {name}")),
            },
        })
    }

    // ------------------------------------------------------------------
    // Hardware task-ID assignment (fusion happened during building;
    // recycling happens here — paper §V-C)
    // ------------------------------------------------------------------

    fn assign_hw_ids(&mut self) -> LResult<(Vec<TaskDef>, Option<u8>)> {
        let n = self.tasks.len();
        if n == 0 {
            return Ok((vec![], None));
        }
        if n > 250 {
            return err(format!(
                "OOR: class {} has {} logical tasks (limit 250)",
                self.region.name, n
            ));
        }
        // Data tasks are pinned to their color's ID.
        // Local tasks: slot per phase (recycling) or globally unique.
        let top = self.cfg.max_task_ids - 1; // e.g. 27
        let mut hw: Vec<u8> = vec![0; n];
        let colors_in_use = self.alloc.colors_used.len() as u8;

        let mut slot_of: Vec<usize> = vec![0; n];
        if self.opts.recycling {
            let mut next_slot: HashMap<usize, usize> = HashMap::new(); // phase → slot
            for (i, t) in self.tasks.iter().enumerate() {
                if matches!(t.kind, LTaskKind::Data { .. }) {
                    continue;
                }
                let s = next_slot.entry(t.phase).or_insert(0);
                slot_of[i] = *s;
                *s += 1;
            }
        } else {
            for (i, t) in self.tasks.iter().enumerate() {
                if matches!(t.kind, LTaskKind::Data { .. }) {
                    continue;
                }
                slot_of[i] = i;
            }
        }
        let max_slot = self
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.kind, LTaskKind::Local))
            .map(|(i, _)| slot_of[i])
            .max()
            .unwrap_or(0);
        if (max_slot as i64) > top as i64 - colors_in_use as i64 {
            return err(format!(
                "OOR: class {} needs {} local task IDs but only {} remain \
                 ({} colors share the ID space){}",
                self.region.name,
                max_slot + 1,
                top as i64 - colors_in_use as i64 + 1,
                colors_in_use,
                if self.opts.recycling { "" } else { " — enable task recycling" },
            ));
        }
        for (i, t) in self.tasks.iter().enumerate() {
            hw[i] = match &t.kind {
                LTaskKind::Data { color, .. } => *color,
                LTaskKind::Local => top - slot_of[i] as u8,
            };
        }

        // Patch task-control actions from logical indices to hw IDs, and
        // add dispatch-state selection for recycled IDs.
        let mut share_count: HashMap<u8, usize> = HashMap::new();
        for (i, t) in self.tasks.iter().enumerate() {
            if matches!(t.kind, LTaskKind::Local) {
                *share_count.entry(hw[i]).or_insert(0) += 1;
            }
        }
        // One state register per recycled hardware ID, from 31 downward.
        let mut state_reg: HashMap<u8, u8> = HashMap::new();
        {
            let mut next = STATE_REG_TOP;
            let mut shared: Vec<u8> =
                share_count.iter().filter(|(_, &n)| n > 1).map(|(&id, _)| id).collect();
            shared.sort_unstable();
            for id in shared {
                if next <= SCRATCH_REG {
                    return err(format!(
                        "OOR: class {} recycles more than {} task IDs (state registers exhausted)",
                        self.region.name,
                        STATE_REG_TOP - SCRATCH_REG
                    ));
                }
                state_reg.insert(id, next);
                next -= 1;
            }
        }
        // Branch index of each logical task within its hw ID (by phase
        // order = creation order).
        let mut branch_idx: Vec<usize> = vec![0; n];
        {
            let mut seen: HashMap<u8, usize> = HashMap::new();
            for i in 0..n {
                if matches!(self.tasks[i].kind, LTaskKind::Local) {
                    let c = seen.entry(hw[i]).or_insert(0);
                    branch_idx[i] = *c;
                    *c += 1;
                }
            }
        }
        let needs_dispatch: Vec<bool> = (0..n)
            .map(|i| {
                matches!(self.tasks[i].kind, LTaskKind::Local)
                    && share_count.get(&hw[i]).copied().unwrap_or(0) > 1
            })
            .collect();

        // Rewrite actions.
        for t in 0..n {
            let mut body = std::mem::take(&mut self.tasks[t].body);
            patch_actions(&mut body, &|logical: u8| {
                let li = logical as usize;
                let mut a = TaskAction {
                    kind: TaskActionKind::Activate, // kind preserved by caller
                    task: hw[li],
                    set_reg: None,
                };
                if needs_dispatch[li] {
                    a.set_reg = Some((state_reg[&hw[li]], branch_idx[li] as i64));
                }
                a
            });
            self.tasks[t].body = body;
        }

        // Emit TaskDefs: merge recycled locals into dispatch state
        // machines.
        let mut defs: Vec<TaskDef> = vec![];
        let mut done: Vec<bool> = vec![false; n];
        for i in 0..n {
            if done[i] {
                continue;
            }
            match &self.tasks[i].kind {
                LTaskKind::Data { color, wavelet_reg } => {
                    done[i] = true;
                    defs.push(TaskDef {
                        name: self.tasks[i].name.clone(),
                        hw_id: hw[i],
                        kind: TaskKind::Data { color: *color, wavelet_reg: *wavelet_reg },
                        initially_active: true,
                        initially_blocked: self.tasks[i].blocked,
                        body: std::mem::take(&mut self.tasks[i].body),
                    });
                }
                LTaskKind::Local => {
                    let id = hw[i];
                    let members: Vec<usize> = (i..n)
                        .filter(|&j| {
                            !done[j] && hw[j] == id && matches!(self.tasks[j].kind, LTaskKind::Local)
                        })
                        .collect();
                    for &j in &members {
                        done[j] = true;
                    }
                    if members.len() == 1 {
                        let j = members[0];
                        defs.push(TaskDef {
                            name: self.tasks[j].name.clone(),
                            hw_id: id,
                            kind: TaskKind::Local,
                            initially_active: false,
                            initially_blocked: self.tasks[j].blocked,
                            body: std::mem::take(&mut self.tasks[j].body),
                        });
                    } else {
                        // Dispatch state machine: snapshot the selector at
                        // entry (branches may set other selectors), then
                        // branch on the snapshot.
                        let sreg = state_reg[&id];
                        let mut body: Vec<MOp> =
                            vec![MOp::SetReg { reg: SCRATCH_REG, val: SExpr::Reg(sreg) }];
                        for (bi, &j) in members.iter().enumerate() {
                            let mut b = std::mem::take(&mut self.tasks[j].body);
                            // Re-block before the next 2-pred occurrence.
                            if let Some(&jn) = members.get(bi + 1) {
                                if self.tasks[jn].two_pred_join {
                                    b.insert(
                                        0,
                                        MOp::Control(TaskAction {
                                            kind: TaskActionKind::Block,
                                            task: id,
                                            set_reg: None,
                                        }),
                                    );
                                }
                            }
                            body.push(MOp::If {
                                cond: SExpr::bin(
                                    SBinOp::Eq,
                                    SExpr::Reg(SCRATCH_REG),
                                    SExpr::imm(branch_idx[j] as i64),
                                ),
                                then_ops: b,
                                else_ops: vec![],
                            });
                        }
                        defs.push(TaskDef {
                            name: format!("dispatch_{id}"),
                            hw_id: id,
                            kind: TaskKind::Local,
                            initially_active: false,
                            initially_blocked: self.tasks[members[0]].blocked,
                            body,
                        });
                    }
                }
            }
        }
        // Logical task 0 is the class entry.
        Ok((defs, Some(hw[0])))
    }
}

/// Rewrite every TaskAction target in a body from logical index to hw id
/// (the rewriter preserves the action kind, merging in dispatch state).
fn patch_actions(ops: &mut [MOp], f: &dyn Fn(u8) -> TaskAction) {
    for op in ops {
        match op {
            MOp::Control(a) => {
                let n = f(a.task);
                a.task = n.task;
                if a.set_reg.is_none() {
                    a.set_reg = n.set_reg;
                }
            }
            MOp::Dsd(d) => {
                for a in &mut d.on_complete {
                    let n = f(a.task);
                    a.task = n.task;
                    if a.set_reg.is_none() {
                        a.set_reg = n.set_reg;
                    }
                }
            }
            MOp::If { then_ops, else_ops, .. } => {
                patch_actions(then_ops, f);
                patch_actions(else_ops, f);
            }
            MOp::For { body, .. } => patch_actions(body, f),
            _ => {}
        }
    }
}

/// Does an expression reference variable `k`?
fn contains_var(e: &Expr, k: &str) -> bool {
    match e {
        Expr::Ident(n) => n == k,
        Expr::Int(_) | Expr::Float(_) => false,
        Expr::Index(b, idx) => contains_var(b, k) || idx.iter().any(|i| contains_var(i, k)),
        Expr::Unary(_, a) => contains_var(a, k),
        Expr::Bin(_, a, b) => contains_var(a, k) || contains_var(b, k),
        Expr::Cond { then, cond, els } => {
            contains_var(then, k) || contains_var(cond, k) || contains_var(els, k)
        }
        Expr::Call(_, args) => args.iter().any(|a| contains_var(a, k)),
    }
}

/// Count receive-from-arg and send-to-arg statements per (arg, field).
fn scan_arg_io(
    stmts: &[ir::Stmt],
    recv: &mut HashMap<(String, String), usize>,
    send: &mut HashMap<(String, String), usize>,
) {
    for s in stmts {
        match s {
            ir::Stmt::Recv { dst, stream: ir::StreamRef::Arg { name, .. }, .. } => {
                // Only whole-field receives are alias candidates.
                let f = match dst {
                    Expr::Ident(f) => f.clone(),
                    _ => String::new(),
                };
                *recv.entry((name.clone(), f)).or_insert(0) += 1;
            }
            ir::Stmt::Send { data, stream: ir::StreamRef::Arg { name, .. }, .. } => {
                // Only whole-field sends are alias candidates.
                let f = match data {
                    Expr::Ident(f) => f.clone(),
                    _ => String::new(),
                };
                *send.entry((name.clone(), f)).or_insert(0) += 1;
            }
            ir::Stmt::ForeachRecv { body, .. }
            | ir::Stmt::Map { body, .. }
            | ir::Stmt::For { body, .. }
            | ir::Stmt::Async { body, .. } => scan_arg_io(body, recv, send),
            ir::Stmt::If { then_body, else_body, .. } => {
                scan_arg_io(then_body, recv, send);
                scan_arg_io(else_body, recv, send);
            }
            _ => {}
        }
    }
}

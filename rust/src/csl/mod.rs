//! CSL backend (paper §V).
//!
//! [`compile`] drives the full pipeline from an instantiated SpaDA IR
//! program to (a) a loadable [`crate::machine::MachineProgram`] — the
//! "binary" the WSE-2 simulator executes — and (b) CSL-like source text
//! (one code file per PE equivalence class plus the layout file), used
//! for the Table II lines-of-code accounting and for inspection.

pub mod lower;
pub mod emit;

pub use lower::{lower, LowerResult};

use crate::ir::core as ir;
use crate::machine::{MachineConfig, MachineProgram};
use crate::passes::{self, Options, PassError, PassStats};

/// A compiled kernel.
#[derive(Debug)]
pub struct Compiled {
    pub machine: MachineProgram,
    /// (filename, contents) — per-class code files + layout.csl.
    pub csl_files: Vec<(String, String)>,
    pub stats: PassStats,
}

impl Compiled {
    /// Total CSL lines of code (Table II metric: non-blank lines across
    /// all generated files).
    pub fn csl_loc(&self) -> usize {
        self.csl_files
            .iter()
            .map(|(_, text)| text.lines().filter(|l| !l.trim().is_empty()).count())
            .sum()
    }
}

/// Compile an instantiated SpaDA program for the given machine.
pub fn compile(
    prog: &ir::Program,
    cfg: &MachineConfig,
    opts: &Options,
) -> Result<Compiled, PassError> {
    let cb = passes::checkerboard(prog)?;
    let classes = passes::equivalence_classes(&cb.program);
    let alloc = passes::allocate_colors(&cb.program, cfg)?;
    let mut res = lower(&cb.program, &classes, &alloc, cfg, opts)?;
    res.stats.streams_split = cb.streams_split;
    res.stats.blocks_split = cb.blocks_split;
    res.stats.classes = classes.len();
    res.stats.colors_used = alloc.colors_used.len();
    let csl_files = emit::emit_csl(&res.program, cfg);
    Ok(Compiled { machine: res.program, csl_files, stats: res.stats })
}

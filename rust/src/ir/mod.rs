//! SpaDA intermediate representations.
pub mod core;
pub mod stencil;
pub use core::*;

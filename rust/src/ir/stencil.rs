//! Stencil IR (paper §IV).
//!
//! The intermediate representation between the GT4Py-style frontend and
//! SpaDA. It captures (1) which field accesses cross PE boundaries
//! (horizontal offsets) versus stay local (vertical offsets), (2) the
//! halo regions boundary PEs must satisfy, and (3) types and iteration
//! domains — decoupling stencil semantics from spatial code generation.

use std::collections::BTreeMap;
use std::fmt;

/// Vertical iteration order of a computation region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KOrder {
    /// Levels are independent — fully parallel (vectorizable).
    Parallel,
    /// Sequential bottom-up (k-1 dependencies allowed).
    Forward,
    /// Sequential top-down (k+1 dependencies allowed).
    Backward,
}

/// Half-open vertical interval with Python-slice-like bounds relative to
/// the K levels: `lo..K+hi_rel` where `hi_rel <= 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KInterval {
    pub lo: i64,
    /// Offset from K (0 = K, -1 = K-1, ...).
    pub hi_rel: i64,
}

impl KInterval {
    pub fn full() -> Self {
        KInterval { lo: 0, hi_rel: 0 }
    }
}

/// A field access with a 3-D offset `(di, dj, dk)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Access {
    pub field: String,
    pub di: i64,
    pub dj: i64,
    pub dk: i64,
}

/// Stencil expression (already type-checked to f32).
#[derive(Clone, Debug, PartialEq)]
pub enum SExpr {
    Const(f64),
    Access(Access),
    Neg(Box<SExpr>),
    Add(Box<SExpr>, Box<SExpr>),
    Sub(Box<SExpr>, Box<SExpr>),
    Mul(Box<SExpr>, Box<SExpr>),
    Div(Box<SExpr>, Box<SExpr>),
}

impl SExpr {
    pub fn accesses(&self, out: &mut Vec<Access>) {
        match self {
            SExpr::Const(_) => {}
            SExpr::Access(a) => out.push(a.clone()),
            SExpr::Neg(a) => a.accesses(out),
            SExpr::Add(a, b) | SExpr::Sub(a, b) | SExpr::Mul(a, b) | SExpr::Div(a, b) => {
                a.accesses(out);
                b.accesses(out);
            }
        }
    }
}

/// One statement: `target[0,0,0] = expr`.
#[derive(Clone, Debug, PartialEq)]
pub struct SStmt {
    pub target: String,
    pub expr: SExpr,
}

/// A vertical computation region (`with computation(...) interval(...)`).
#[derive(Clone, Debug, PartialEq)]
pub struct Region {
    pub order: KOrder,
    pub interval: KInterval,
    pub stmts: Vec<SStmt>,
}

/// Field role in the stencil signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldRole {
    Input,
    Output,
    InOut,
    Temporary,
}

/// Per-field halo requirement (elements needed from each neighbour).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Halo {
    pub west: i64,
    pub east: i64,
    pub north: i64,
    pub south: i64,
}

impl Halo {
    pub fn any(&self) -> bool {
        self.west > 0 || self.east > 0 || self.north > 0 || self.south > 0
    }
}

/// The analyzed stencil program.
#[derive(Clone, Debug)]
pub struct StencilIr {
    pub name: String,
    /// Declared fields in signature order.
    pub fields: Vec<String>,
    pub roles: BTreeMap<String, FieldRole>,
    pub halos: BTreeMap<String, Halo>,
    pub regions: Vec<Region>,
    /// Max |dk| used (vertical halo inside the local column).
    pub k_reach: i64,
}

impl StencilIr {
    /// Analyze a parsed stencil definition into the IR (roles + halos).
    pub fn analyze(
        name: &str,
        fields: Vec<String>,
        regions: Vec<Region>,
    ) -> Result<StencilIr, String> {
        let mut roles: BTreeMap<String, FieldRole> = BTreeMap::new();
        let mut halos: BTreeMap<String, Halo> = BTreeMap::new();
        for f in &fields {
            roles.insert(f.clone(), FieldRole::Input);
            halos.insert(f.clone(), Halo::default());
        }
        let mut k_reach = 0i64;
        for region in &regions {
            for stmt in &region.stmts {
                if !roles.contains_key(&stmt.target) {
                    return Err(format!("unknown field {}", stmt.target));
                }
                let mut acc = vec![];
                stmt.expr.accesses(&mut acc);
                for a in &acc {
                    let Some(h) = halos.get_mut(&a.field) else {
                        return Err(format!("unknown field {}", a.field));
                    };
                    if a.di < 0 {
                        h.west = h.west.max(-a.di);
                    }
                    if a.di > 0 {
                        h.east = h.east.max(a.di);
                    }
                    if a.dj < 0 {
                        h.north = h.north.max(-a.dj);
                    }
                    if a.dj > 0 {
                        h.south = h.south.max(a.dj);
                    }
                    k_reach = k_reach.max(a.dk.abs());
                    if a.dk != 0 && region.order == KOrder::Parallel && a.field == stmt.target {
                        return Err(format!(
                            "{}: vertical self-dependency in a PARALLEL region",
                            stmt.target
                        ));
                    }
                }
                // Role updates.
                let read_fields: Vec<String> = acc.iter().map(|a| a.field.clone()).collect();
                let r = roles.get_mut(&stmt.target).unwrap();
                *r = match (*r, read_fields.contains(&stmt.target)) {
                    (FieldRole::Input, false) => FieldRole::Output,
                    (FieldRole::Input, true) => FieldRole::InOut,
                    (other, _) => other,
                };
            }
        }
        Ok(StencilIr { name: name.to_string(), fields, roles, halos, regions, k_reach })
    }

    /// Horizontal offsets that require inter-PE communication, as
    /// (field, di, dj) — one relative stream each (paper §IV: "the four
    /// neighbor accesses become four relative_stream declarations").
    pub fn comm_offsets(&self) -> Vec<(String, i64, i64)> {
        let mut out = vec![];
        for region in &self.regions {
            for stmt in &region.stmts {
                let mut acc = vec![];
                stmt.expr.accesses(&mut acc);
                for a in acc {
                    if a.di != 0 || a.dj != 0 {
                        let key = (a.field.clone(), a.di, a.dj);
                        if !out.contains(&key) {
                            out.push(key);
                        }
                    }
                }
            }
        }
        out
    }

    /// Total FLOPs per grid point (for FLOP/s accounting à la Fig. 6).
    pub fn flops_per_point(&self) -> u64 {
        fn count(e: &SExpr) -> u64 {
            match e {
                SExpr::Const(_) | SExpr::Access(_) => 0,
                SExpr::Neg(a) => count(a),
                SExpr::Add(a, b) | SExpr::Sub(a, b) | SExpr::Mul(a, b) | SExpr::Div(a, b) => {
                    1 + count(a) + count(b)
                }
            }
        }
        self.regions
            .iter()
            .flat_map(|r| r.stmts.iter())
            .map(|s| count(&s.expr))
            .sum()
    }
}

impl fmt::Display for StencilIr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "stencil {} (k_reach={})", self.name, self.k_reach)?;
        for field in &self.fields {
            writeln!(
                f,
                "  field {} role={:?} halo={:?}",
                field, self.roles[field], self.halos[field]
            )?;
        }
        for r in &self.regions {
            writeln!(
                f,
                "  region {:?} [{}..K{:+}] ({} stmts)",
                r.order,
                r.interval.lo,
                r.interval.hi_rel,
                r.stmts.len()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc(f: &str, di: i64, dj: i64, dk: i64) -> SExpr {
        SExpr::Access(Access { field: f.into(), di, dj, dk })
    }

    #[test]
    fn laplacian_analysis() {
        // out = -4*in + in[e] + in[w] + in[s] + in[n]
        let expr = SExpr::Add(
            Box::new(SExpr::Mul(Box::new(SExpr::Const(-4.0)), Box::new(acc("in", 0, 0, 0)))),
            Box::new(SExpr::Add(
                Box::new(SExpr::Add(Box::new(acc("in", 1, 0, 0)), Box::new(acc("in", -1, 0, 0)))),
                Box::new(SExpr::Add(Box::new(acc("in", 0, 1, 0)), Box::new(acc("in", 0, -1, 0)))),
            )),
        );
        let ir = StencilIr::analyze(
            "laplace",
            vec!["in".into(), "out".into()],
            vec![Region {
                order: KOrder::Parallel,
                interval: KInterval::full(),
                stmts: vec![SStmt { target: "out".into(), expr }],
            }],
        )
        .unwrap();
        assert_eq!(ir.roles["out"], FieldRole::Output);
        assert_eq!(ir.roles["in"], FieldRole::Input);
        let h = ir.halos["in"];
        assert_eq!((h.west, h.east, h.north, h.south), (1, 1, 1, 1));
        assert_eq!(ir.comm_offsets().len(), 4);
        assert_eq!(ir.flops_per_point(), 5);
    }

    #[test]
    fn vertical_self_dep_rejected_in_parallel() {
        let expr = SExpr::Add(Box::new(acc("f", 0, 0, -1)), Box::new(SExpr::Const(1.0)));
        let r = StencilIr::analyze(
            "bad",
            vec!["f".into()],
            vec![Region {
                order: KOrder::Parallel,
                interval: KInterval::full(),
                stmts: vec![SStmt { target: "f".into(), expr }],
            }],
        );
        assert!(r.is_err());
    }

    #[test]
    fn forward_region_allows_k_dep() {
        let expr = SExpr::Add(Box::new(acc("f", 0, 0, -1)), Box::new(acc("g", 0, 0, 0)));
        let ir = StencilIr::analyze(
            "cum",
            vec!["f".into(), "g".into()],
            vec![Region {
                order: KOrder::Forward,
                interval: KInterval { lo: 1, hi_rel: 0 },
                stmts: vec![SStmt { target: "f".into(), expr }],
            }],
        )
        .unwrap();
        assert_eq!(ir.roles["f"], FieldRole::InOut);
        assert_eq!(ir.k_reach, 1);
        assert!(ir.comm_offsets().is_empty());
    }
}

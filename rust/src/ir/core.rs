//! The instantiated SpaDA IR.
//!
//! Produced by [`crate::sem::instantiate`]: meta-parameters bound,
//! meta-`for` loops unrolled into phases, subgrids concrete, constant
//! expressions folded, and async/await statements normalized (each
//! asynchronous operation carries an optional completion name and an
//! `awaited` flag instead of wrapper statements).

use crate::machine::Dtype;
use crate::spada::ast::{ArgDir, Expr};
use crate::util::Subgrid;

/// A stream offset per dimension: scalar hop or multicast range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offset {
    Scalar(i64),
    /// Multicast to all offsets in `[lo, hi]` (inclusive of lo, exclusive
    /// of hi, matching SpaDA's `[dx0:dx1]`).
    Range(i64, i64),
}

impl Offset {
    pub fn is_zero(&self) -> bool {
        matches!(self, Offset::Scalar(0))
    }

    /// True if any offset component is non-zero (the dimension is
    /// *active* in the paper's routing terminology).
    pub fn is_active(&self) -> bool {
        !self.is_zero()
    }

    /// Scalar value (multicast ranges have no single value).
    pub fn scalar(&self) -> Option<i64> {
        match self {
            Offset::Scalar(v) => Some(*v),
            Offset::Range(..) => None,
        }
    }
}

/// Kernel argument (I/O port array).
#[derive(Clone, Debug, PartialEq)]
pub struct ArgDecl {
    pub name: String,
    pub elem_ty: Dtype,
    /// Port-array extents (empty = single port).
    pub extents: Vec<i64>,
    pub dir: ArgDir,
}

/// A field allocated by a `place` block.
#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    pub name: String,
    pub ty: Dtype,
    /// Element shape; empty = scalar.
    pub shape: Vec<i64>,
    pub subgrid: Subgrid,
    /// Phase index this field is scoped to (None = kernel lifetime).
    pub phase: Option<usize>,
}

impl Field {
    pub fn elems(&self) -> i64 {
        self.shape.iter().product::<i64>().max(1)
    }

    pub fn bytes(&self) -> i64 {
        self.elems() * self.ty.size() as i64
    }
}

/// A stream declared by a `dataflow` block.
#[derive(Clone, Debug, PartialEq)]
pub struct Stream {
    /// Globally unique stream id.
    pub id: usize,
    pub name: String,
    pub elem_ty: Dtype,
    pub subgrid: Subgrid,
    pub dx: Offset,
    pub dy: Offset,
}

/// Reference to a communication endpoint in send/receive.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamRef {
    /// A dataflow stream (by id).
    Local(usize),
    /// A kernel argument port, e.g. `a_in[i]`.
    Arg { name: String, index: Vec<Expr> },
}

/// Normalized IR statements.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// Asynchronous send of `data` over `stream`.
    Send { data: Expr, stream: StreamRef, completion: Option<String>, awaited: bool },
    /// Whole-array receive into `dst`.
    Recv { dst: Expr, stream: StreamRef, completion: Option<String>, awaited: bool },
    /// `foreach [k,] x in [0:len,] receive(s) { body }`; `len: None` means
    /// stream-driven (data-task fallback).
    ForeachRecv {
        index: Option<String>,
        elem: String,
        len: Option<Expr>,
        stream: StreamRef,
        body: Vec<Stmt>,
        completion: Option<String>,
        awaited: bool,
    },
    /// Parallelizable affine loop (vectorization candidate).
    Map {
        vars: Vec<String>,
        ranges: Vec<(Expr, Expr, Expr)>,
        body: Vec<Stmt>,
        completion: Option<String>,
        awaited: bool,
    },
    /// Sequential loop.
    For { var: String, range: (Expr, Expr, Expr), body: Vec<Stmt> },
    /// Grouped asynchronous statements.
    Async { body: Vec<Stmt>, completion: Option<String>, awaited: bool },
    /// Wait on a named completion.
    Await { completion: String },
    /// Local barrier on all pending completions.
    AwaitAll,
    /// Scalar / element assignment.
    Assign { lhs: Expr, rhs: Expr },
    /// Local scalar declaration.
    Let { ty: Dtype, name: String, init: Expr },
    /// Runtime conditional (condition may reference PE coords).
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt> },
}

impl Stmt {
    /// The completion name attached to this statement, if any.
    pub fn completion(&self) -> Option<&str> {
        match self {
            Stmt::Send { completion, .. }
            | Stmt::Recv { completion, .. }
            | Stmt::ForeachRecv { completion, .. }
            | Stmt::Map { completion, .. }
            | Stmt::Async { completion, .. } => completion.as_deref(),
            _ => None,
        }
    }

    /// True for statements with asynchronous semantics.
    pub fn is_async_op(&self) -> bool {
        matches!(
            self,
            Stmt::Send { .. }
                | Stmt::Recv { .. }
                | Stmt::ForeachRecv { .. }
                | Stmt::Map { .. }
                | Stmt::Async { .. }
        )
    }

    pub fn is_awaited(&self) -> bool {
        match self {
            Stmt::Send { awaited, .. }
            | Stmt::Recv { awaited, .. }
            | Stmt::ForeachRecv { awaited, .. }
            | Stmt::Map { awaited, .. }
            | Stmt::Async { awaited, .. } => *awaited,
            _ => true,
        }
    }
}

/// A compute block over a concrete subgrid.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeBlock {
    pub subgrid: Subgrid,
    /// Names bound to the PE coordinates (usually "i", "j").
    pub coord_vars: (String, String),
    pub stmts: Vec<Stmt>,
}

/// One phase: streams + compute blocks (place decls are hoisted into
/// [`Program::fields`] with their phase recorded).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Phase {
    pub streams: Vec<Stream>,
    pub computes: Vec<ComputeBlock>,
}

/// A fully instantiated SpaDA program.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    pub name: String,
    pub args: Vec<ArgDecl>,
    pub fields: Vec<Field>,
    pub phases: Vec<Phase>,
}

impl Program {
    pub fn stream(&self, id: usize) -> Option<&Stream> {
        self.phases.iter().flat_map(|p| p.streams.iter()).find(|s| s.id == id)
    }

    pub fn field(&self, name: &str) -> Option<&Field> {
        self.fields.iter().find(|f| f.name == name)
    }

    pub fn arg(&self, name: &str) -> Option<&ArgDecl> {
        self.args.iter().find(|a| a.name == name)
    }

    /// Union bounding box of all subgrids (fabric region the kernel uses).
    pub fn extent(&self) -> (i64, i64) {
        let mut w = 0;
        let mut h = 0;
        let mut seen = |g: &Subgrid| {
            if let Some(l) = g.dims[0].last() {
                w = w.max(l + 1);
            }
            if let Some(l) = g.dims[1].last() {
                h = h.max(l + 1);
            }
        };
        for f in &self.fields {
            seen(&f.subgrid);
        }
        for p in &self.phases {
            for s in &p.streams {
                seen(&s.subgrid);
            }
            for c in &p.computes {
                seen(&c.subgrid);
            }
        }
        (w, h)
    }
}

//! PJRT runtime bridge — the numerical oracle.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (Layer-2 JAX models wrapping Layer-1 Pallas kernels), compiles them on
//! the PJRT CPU client via the `xla` crate, and executes them with
//! concrete inputs. The harness compares WSE-2 simulator outputs against
//! these executions — Python never runs at simulation time.
//!
//! The `xla` crate is not available in offline builds, so the PJRT
//! client is gated behind the `pjrt` cargo feature. The default build
//! ships an API-compatible stub whose [`Runtime::new`] reports the
//! oracle as unavailable; callers (the `verify` harness, the
//! `stencil_pipeline` example) degrade gracefully.

use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Max |a-b| relative error helper used across the harness.
pub fn max_rel_err(got: &[f32], want: &[f32]) -> f32 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
        .fold(0.0f32, f32::max)
}

/// A concrete f32 input tensor.
pub struct Input<'a> {
    pub data: &'a [f32],
    pub dims: Vec<i64>,
}

impl<'a> Input<'a> {
    pub fn new(data: &'a [f32], dims: &[i64]) -> Input<'a> {
        assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>().max(1),
            "data/dims mismatch"
        );
        Input { data, dims: dims.to_vec() }
    }

    /// Scalar input.
    pub fn scalar(v: &'a [f32]) -> Input<'a> {
        Input { data: v, dims: vec![] }
    }
}

// ---------------------------------------------------------------------
// Real PJRT client (requires the vendored `xla` crate).
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// A loaded, compiled AOT artifact.
    pub struct Oracle {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    /// Shared PJRT CPU client (one per process).
    pub struct Runtime {
        client: xla::PjRtClient,
        artifact_dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU runtime reading artifacts from `dir`.
        pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            Ok(Runtime { client, artifact_dir: dir.as_ref().to_path_buf() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile `<name>.hlo.txt`.
        pub fn load(&self, name: &str) -> Result<Oracle> {
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            Ok(Oracle { exe, name: name.to_string() })
        }
    }

    impl Oracle {
        /// Execute with f32 inputs; returns the flattened f32 outputs of
        /// the result tuple.
        pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for inp in inputs {
                let lit = xla::Literal::vec1(inp.data);
                let lit = if inp.dims.is_empty() {
                    // 0-d scalar: reshape from [1].
                    lit.reshape(&[]).map_err(|e| anyhow!("scalar reshape: {e:?}"))?
                } else {
                    lit.reshape(&inp.dims)
                        .map_err(|e| anyhow!("reshape to {:?}: {e:?}", inp.dims))?
                };
                lits.push(lit);
            }
            let mut result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
            // aot.py lowers with return_tuple=True.
            let elems = result
                .decompose_tuple()
                .map_err(|e| anyhow!("tuple {}: {e:?}", self.name))?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>().map_err(|err| anyhow!("to_vec: {err:?}"))?);
            }
            Ok(out)
        }
    }
}

// ---------------------------------------------------------------------
// Offline stub: same API, reports the oracle as unavailable.
// ---------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use super::*;

    /// Stub oracle (never constructed — [`Runtime::new`] fails first).
    pub struct Oracle {
        pub name: String,
    }

    /// Stub PJRT runtime: construction reports the missing backend.
    pub struct Runtime {
        #[allow(dead_code)]
        artifact_dir: PathBuf,
    }

    impl Runtime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
            let _ = dir.as_ref();
            Err(anyhow!(
                "PJRT runtime unavailable: built without the `pjrt` feature \
                 (vendor the `xla` crate and build with `--features pjrt`)"
            ))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, name: &str) -> Result<Oracle> {
            Err(anyhow!("PJRT runtime unavailable: cannot load oracle {name}"))
        }
    }

    impl Oracle {
        pub fn run(&self, _inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!("PJRT runtime unavailable: cannot execute oracle {}", self.name))
        }
    }
}

pub use pjrt_impl::{Oracle, Runtime};

impl Runtime {
    /// Default artifact directory relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // Works from the repo root (cargo run / cargo test).
        PathBuf::from("artifacts")
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("reduce_16x64.hlo.txt").exists() {
            eprintln!("artifacts not built; skipping PJRT test");
            return None;
        }
        Some(Runtime::new(dir).expect("pjrt cpu client"))
    }

    #[test]
    fn reduce_oracle_runs() {
        let Some(rt) = runtime() else { return };
        let oracle = rt.load("reduce_16x64").unwrap();
        let data: Vec<f32> = (0..16 * 64).map(|i| (i % 7) as f32).collect();
        let out = oracle.run(&[Input::new(&data, &[16, 64])]).unwrap();
        assert_eq!(out[0].len(), 64);
        let want: Vec<f32> = (0..64)
            .map(|k| (0..16).map(|p| ((p * 64 + k) % 7) as f32).sum())
            .collect();
        assert!(max_rel_err(&out[0], &want) < 1e-5);
    }

    #[test]
    fn gemv_oracle_runs() {
        let Some(rt) = runtime() else { return };
        let oracle = rt.load("gemv_64x48").unwrap();
        let a: Vec<f32> = (0..64 * 48).map(|i| ((i % 13) as f32) * 0.1).collect();
        let x: Vec<f32> = (0..48).map(|i| (i % 5) as f32).collect();
        let y: Vec<f32> = vec![1.0; 64];
        let out = oracle
            .run(&[
                Input::new(&a, &[64, 48]),
                Input::new(&x, &[48]),
                Input::new(&y, &[64]),
                Input::scalar(&[2.0]),
                Input::scalar(&[0.5]),
            ])
            .unwrap();
        let want: Vec<f32> = (0..64)
            .map(|r| {
                let dot: f32 =
                    (0..48).map(|c| ((r * 48 + c) % 13) as f32 * 0.1 * ((c % 5) as f32)).sum();
                2.0 * dot + 0.5
            })
            .collect();
        assert!(max_rel_err(&out[0], &want) < 1e-4, "{:?}", &out[0][..4]);
    }

    #[test]
    fn max_rel_err_zero_on_equal() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::new(Runtime::default_dir()).err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn max_rel_err_zero_on_equal() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }
}

//! Resilience campaign (`spada faults --campaign`): sweep single-fault
//! sites across the library kernels, classify every faulted run against
//! its clean reference, and emit a JSONL resilience matrix plus a
//! per-kernel summary table.
//!
//! Site enumeration is taken from each kernel's *planned flows* — every
//! mesh link an actual flow occupies (times a grid of injection cycles),
//! every placed PE (halts), and every flow source (payload corruption).
//! Ramp transfers never appear in `PlannedFlow::links`, so ramp sites
//! are structurally absent rather than silently inert.
//!
//! Determinism: rows are produced into a site-indexed table (worker
//! interleaving cannot reorder them — the fleet's [`pool`] provides
//! exactly that contract), every run stages the same seeded inputs,
//! and the engines guarantee bit-identical faulted runs across
//! `SPADA_THREADS` — so the matrix file is byte-identical at any thread
//! count (the CI gate diffs thread counts 1 and 4). Each kernel
//! compiles once through the fleet [`PlanCache`]; every faulted site
//! reuses that compilation with an explicit per-run [`SimOptions`]
//! fault plan, so ambient `SPADA_FAULTS` / `SPADA_TIMEOUT_MS` can
//! never leak into the matrix (only the inner thread count is taken
//! from the environment, to keep the cross-thread CI diff meaningful).
//!
//! [`pool`]: crate::fleet::pool

use crate::fleet::{pool, PlanCache};
use crate::harness::common::{output_words, scaled_binds, stage_kernel_inputs};
use crate::kernels::CompiledKernel;
use crate::machine::fault::{classify, FaultPlan, FaultSpec, Outcome};
use crate::machine::{Direction, MachineConfig, SimOptions};
use crate::passes::Options;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// The kernels the campaign sweeps: the whole registry — the six
/// dense paper kernels plus the sparse SpMV variants (their seeded
/// demo matrices stage through [`stage_kernel_inputs`], so sparse
/// subjects run a real CSR workload, not noise-shaped pointers).
pub fn campaign_kernels() -> Vec<&'static str> {
    crate::kernels::names()
}

/// Input seed shared by the clean reference and every faulted run.
const INPUT_SEED: u64 = 0xCAFE;

/// Campaign configuration (CLI surface of `spada faults`).
pub struct CampaignOpts {
    /// Trim the sweep for CI: one injection time per site.
    pub quick: bool,
    /// Restrict to one kernel (default: all of [`campaign_kernels`]).
    pub kernel: Option<String>,
    /// Injection-time grid points per site (ignored under `quick`).
    pub grid: usize,
    /// JSONL output path.
    pub out: String,
}

impl Default for CampaignOpts {
    fn default() -> CampaignOpts {
        CampaignOpts {
            quick: false,
            kernel: None,
            grid: 4,
            out: "FAULTS_matrix.jsonl".to_string(),
        }
    }
}

/// One resilience-matrix row.
struct Row {
    kernel: &'static str,
    site: String,
    kind: &'static str,
    outcome: Outcome,
    cycles: u64,
}

impl Row {
    fn to_jsonl(&self) -> String {
        let mut detail = self.outcome.detail();
        if detail.len() > 160 {
            detail.truncate(160);
            detail.push('…');
        }
        format!(
            "{{\"kernel\":\"{}\",\"site\":\"{}\",\"kind\":\"{}\",\"outcome\":\"{}\",\
             \"cycles\":{},\"detail\":\"{}\"}}",
            self.kernel,
            esc(&self.site),
            self.kind,
            self.outcome.label(),
            self.cycles,
            esc(&detail),
        )
    }
}

fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// One compiled kernel (shared out of the [`PlanCache`]) plus its
/// clean-run reference.
struct Subject {
    name: &'static str,
    ck: Arc<CompiledKernel>,
    /// Density knob the subject was compiled at — faulted runs must
    /// stage the identical workload (sparse staging depends on it).
    k: i64,
    reference: Vec<(String, Vec<u32>)>,
    clean_cycles: u64,
}

/// Compile a kernel at campaign scale (through the fleet plan cache —
/// repeated campaigns in one process reuse the compilation) and
/// produce its clean reference run. [`MachineConfig::with_grid`] is
/// pure and `base` carries no fault plan or watchdog, so the reference
/// really is clean even inside an armed environment; the simulator's
/// event budget is the (deterministic) backstop, so the matrix never
/// depends on host speed.
fn prepare(
    name: &'static str,
    quick: bool,
    cache: &PlanCache,
    base: &SimOptions,
) -> Result<Subject> {
    let k = if quick { 4 } else { 8 };
    let (binds, w, h) = scaled_binds(name, 4, k)?;
    let cfg = MachineConfig::with_grid(w, h);
    let ck = cache
        .get(name, &binds, &cfg, &Options::default())
        .map_err(anyhow::Error::msg)
        .with_context(|| format!("compiling {name} for the fault campaign"))?;
    let mut sim = ck.simulator_with(base)?;
    stage_kernel_inputs(&mut sim, name, 4, k, INPUT_SEED)?;
    let report = sim.run().map_err(|e| anyhow!("clean {name} run failed: {e}"))?;
    let reference = output_words(&sim);
    Ok(Subject { name, ck, k, reference, clean_cycles: report.cycles })
}

/// Enumerate this subject's single-fault sites, in a deterministic
/// order: link kills (site-major, then time), PE halts (likewise),
/// then one corruption per flow source.
fn sites(s: &Subject, times: &[u64]) -> Vec<FaultSpec> {
    let plan = &s.ck.plan;
    // Every mesh link any planned flow occupies, decoded from its
    // dense slot: slot = (y·width + x)·5 + dir.
    let mut links: Vec<(i64, i64, usize)> = plan
        .flows
        .iter()
        .filter(|f| f.error.is_none())
        .flat_map(|f| f.links.iter().map(|&(li, _)| li))
        .map(|li| {
            let cell = (li / 5) as i64;
            (cell % plan.width, cell / plan.width, (li % 5) as usize)
        })
        .collect();
    links.sort_unstable();
    links.dedup();
    let mut specs = Vec::new();
    for &(x, y, d) in &links {
        for &at in times {
            specs.push(FaultSpec::LinkKill { x, y, dir: Direction::ALL[d], at });
        }
    }
    for p in &plan.pes {
        for &at in times {
            specs.push(FaultSpec::PeHalt { x: p.x, y: p.y, at });
        }
    }
    let mut srcs: Vec<(i64, i64, u8)> = plan
        .flows
        .iter()
        .filter(|f| f.error.is_none())
        .map(|f| (f.src.0, f.src.1, f.color))
        .collect();
    srcs.sort_unstable();
    srcs.dedup();
    for (x, y, color) in srcs {
        specs.push(FaultSpec::Corrupt { x, y, color, at: 0 });
    }
    specs
}

/// Run one faulted site and classify it against the clean reference.
/// The shared compilation is reused; only the per-run [`SimOptions`]
/// differ (the single-fault plan rides on top of `base`).
fn run_site(s: &Subject, spec: FaultSpec, base: &SimOptions) -> Result<Row> {
    let opts = base.clone().faults(FaultPlan::single(spec));
    let mut sim =
        s.ck.simulator_with(&opts).map_err(|e| anyhow!("{}: site {spec}: {e}", s.name))?;
    stage_kernel_inputs(&mut sim, s.name, 4, s.k, INPUT_SEED)?;
    let result = sim.run();
    let outputs = output_words(&sim);
    let cycles = result.as_ref().map(|r| r.cycles).unwrap_or(0);
    let kind = match spec {
        FaultSpec::LinkKill { .. } => "link-kill",
        FaultSpec::LinkSlow { .. } => "link-slow",
        FaultSpec::PeHalt { .. } => "pe-halt",
        FaultSpec::Corrupt { .. } => "corrupt",
        FaultSpec::Delay { .. } => "delay",
    };
    Ok(Row {
        kernel: s.name,
        site: spec.to_string(),
        kind,
        outcome: classify(&result, &outputs, &s.reference),
        cycles,
    })
}

/// Run the full campaign: every subject's sites through a worker pool,
/// rows written site-indexed (deterministic order), summary to stdout.
pub fn campaign(opts: &CampaignOpts) -> Result<()> {
    let all = campaign_kernels();
    let selected: Vec<&'static str> = match &opts.kernel {
        None => all,
        Some(k) => {
            let Some(&name) = all.iter().find(|&&n| n == k.as_str()) else {
                return Err(anyhow!(
                    "unknown campaign kernel {k} (try: {})",
                    all.join(", ")
                ));
            };
            vec![name]
        }
    };
    let grid = if opts.quick { 1 } else { opts.grid.max(1) };

    // Per-run options: only the inner thread count is taken from the
    // environment (so the CI cross-thread byte-identity diff still
    // exercises different engine widths); ambient fault plans,
    // watchdogs and buffer caps never reach the campaign.
    let base = SimOptions { threads: SimOptions::from_env().threads, ..SimOptions::default() };

    // Phase 1: compile + clean reference per kernel (serial: compilation
    // is cheap and the reference is each subject's shared baseline).
    // One cache for the whole campaign — each kernel compiles once.
    let cache = PlanCache::new();
    let mut subjects = Vec::new();
    for &name in &selected {
        subjects.push(prepare(name, opts.quick, &cache, &base)?);
    }

    // Phase 2: enumerate (subject, spec) work items.
    let mut work: Vec<(usize, FaultSpec)> = Vec::new();
    for (si, s) in subjects.iter().enumerate() {
        // Injection times spread over the clean run: t_i = c·i/grid
        // (quick sweeps the midpoint only — t=0 halts trivially kill
        // everything; mid-run faults are the interesting regime).
        let c = s.clean_cycles.max(1);
        let times: Vec<u64> = if grid == 1 {
            vec![c / 2]
        } else {
            (0..grid as u64).map(|i| c * i / grid as u64).collect()
        };
        for spec in sites(s, &times) {
            work.push((si, spec));
        }
    }

    // Phase 3: the fleet worker pool over the site list; results come
    // back index-ordered, so output order is independent of scheduling.
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    let rows = pool::run_indexed(
        work.len(),
        workers,
        |i| {
            let (si, spec) = work[i];
            run_site(&subjects[si], spec, &base)
        },
        |_, _| {},
    );

    // Phase 4: emit JSONL + summary.
    let mut jsonl = String::new();
    let mut summary: Vec<(&'static str, [u64; 7])> =
        selected.iter().map(|&n| (n, [0u64; 7])).collect();
    const LABELS: [&str; 7] =
        ["correct", "sdc", "buffer-deadlock", "circular-wait", "runaway", "timeout", "error"];
    for row in rows {
        let row = row?;
        let li = LABELS
            .iter()
            .position(|&l| l == row.outcome.label())
            .expect("outcome labels are closed");
        summary.iter_mut().find(|(n, _)| *n == row.kernel).expect("known kernel").1[li] += 1;
        jsonl.push_str(&row.to_jsonl());
        jsonl.push('\n');
    }
    std::fs::write(&opts.out, &jsonl)
        .with_context(|| format!("writing resilience matrix to {}", opts.out))?;

    println!("resilience matrix: {} rows -> {}", jsonl.lines().count(), opts.out);
    println!(
        "{:<18} {:>8} {:>6} {:>12} {:>13} {:>8} {:>8} {:>6}",
        "kernel", "correct", "sdc", "buf-deadlock", "circular-wait", "runaway", "timeout", "error"
    );
    for (name, counts) in &summary {
        println!(
            "{:<18} {:>8} {:>6} {:>12} {:>13} {:>8} {:>8} {:>6}",
            name, counts[0], counts[1], counts[2], counts[3], counts[4], counts[5], counts[6]
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_enumeration_is_deterministic_and_nonempty() {
        let s = prepare("chain_reduce", true, &PlanCache::new(), &SimOptions::default()).unwrap();
        let a = sites(&s, &[10]);
        let b = sites(&s, &[10]);
        assert!(!a.is_empty());
        assert_eq!(a, b);
        // Kill sites come from real flow links: every one compiles.
        for spec in &a {
            let fp = FaultPlan::single(*spec);
            crate::machine::FaultSet::compile(&fp, &s.ck.cfg, &s.ck.plan)
                .expect("campaign sites always compile")
                .expect("non-empty plan");
        }
    }

    #[test]
    fn corrupt_site_classifies_as_sdc() {
        let cache = PlanCache::new();
        let s = prepare("chain_reduce", true, &cache, &SimOptions::default()).unwrap();
        assert_eq!(cache.compiles(), 1, "campaign subjects compile through the cache");
        let spec = sites(&s, &[0])
            .into_iter()
            .find(|sp| matches!(sp, FaultSpec::Corrupt { .. }))
            .expect("chain_reduce has flow sources");
        let row = run_site(&s, spec, &SimOptions::default()).unwrap();
        assert_eq!(row.outcome.label(), "sdc", "corruption must be detected: {:?}", row.outcome);
    }
}

//! Fig. 9: compiler-pass ablation study — performance and PE resource
//! utilization with task fusion, task-ID recycling and copy elimination
//! disabled. OOR/OOM outcomes are first-class results (the paper's tree
//! reduce "would not compile" without recycling + fusion).

use super::common::{compile_stencil, run_reduce, run_stencil};
use crate::bench::Table;
use crate::kernels;
use crate::machine::MachineConfig;
use crate::passes::Options;
use anyhow::Result;
use std::time::Instant;

const VARIANTS: &[(&str, Options)] = &[
    ("all-on", Options { fusion: true, recycling: true, copy_elim: true, check: true }),
    ("no-fusion", Options { fusion: false, recycling: true, copy_elim: true, check: true }),
    ("no-recycle", Options { fusion: true, recycling: false, copy_elim: true, check: true }),
    ("no-copyelim", Options { fusion: true, recycling: true, copy_elim: false, check: true }),
    ("none", Options { fusion: false, recycling: false, copy_elim: false, check: true }),
];

fn row_of(
    name: &str,
    variant: &str,
    res: Result<(u64, usize, usize, u32)>,
    wall_ms: f64,
    table: &mut Table,
) {
    match res {
        Ok((cycles, colors, task_ids, mem)) => table.row(&[
            name.to_string(),
            variant.to_string(),
            cycles.to_string(),
            colors.to_string(),
            task_ids.to_string(),
            format!("{:.1}KB", mem as f64 / 1024.0),
            format!("{wall_ms:.1}"),
        ]),
        Err(e) => {
            let what = if e.to_string().contains("OOM") {
                "OOM"
            } else if e.to_string().contains("OOR") {
                "OOR"
            } else {
                "ERR"
            };
            table.row(&[
                name.to_string(),
                variant.to_string(),
                what.to_string(),
                "-".into(),
                "-".into(),
                what.to_string(),
                format!("{wall_ms:.1}"),
            ]);
        }
    }
}

pub fn run(quick: bool) -> Result<()> {
    let mut table =
        Table::new(&["kernel", "variant", "cycles", "colors", "taskIDs", "mem/PE", "wall ms"]);

    // (a) UVBKE stencil (paper: 746x990x320).
    let (nx, ny, k) = if quick { (8i64, 8i64, 16i64) } else { (32, 32, 320) };
    for (vname, opts) in VARIANTS {
        let t0 = Instant::now();
        let res = run_stencil("uvbke", nx, ny, k, opts).map(|r| {
            (
                r.run.report.cycles,
                r.run.stats.colors_used,
                r.run.stats.hw_task_ids,
                r.run.stats.mem_bytes_max,
            )
        });
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        row_of("uvbke", vname, res.map_err(anyhow::Error::from), wall_ms, &mut table);
    }

    // (b) Tree 2-D reduce, 1 KB message (paper: 512x512; needs
    // 2·log2(P) colors and per-level tasks → OOR without recycling).
    let g = if quick { 16 } else { 64 };
    for (vname, opts) in VARIANTS {
        let t0 = Instant::now();
        let res = run_reduce("tree_reduce", g, g, 256, opts).map(|(r, _)| {
            (r.report.cycles, r.stats.colors_used, r.stats.hw_task_ids, r.stats.mem_bytes_max)
        });
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        row_of("tree_reduce(1KB)", vname, res, wall_ms, &mut table);
    }

    // (c) Two-phase 2-D reduce, 16 KB message (paper: staging buffers
    // exhaust the 48 KB PE memory without copy elimination).
    let k16 = 4096; // 16 KB of f32
    for (vname, opts) in VARIANTS {
        let t0 = Instant::now();
        let res = run_reduce("two_phase_reduce", g, g, k16, opts).map(|(r, _)| {
            (r.report.cycles, r.stats.colors_used, r.stats.hw_task_ids, r.stats.mem_bytes_max)
        });
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        row_of("two_phase(16KB)", vname, res, wall_ms, &mut table);
    }

    table.print();
    println!("(paper Fig. 9: optimizations improve runtime and memory; tree reduce is OOR \
              without recycling/fusion; two-phase 16KB is OOM without copy elimination)");
    let _ = compile_stencil; // used by perf pass
    let _ = MachineConfig::wse2;
    let _ = kernels::sources;
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig9_quick() {
        super::run(true).unwrap();
    }
}

//! Shared harness plumbing: compile + simulate kernels with synthetic
//! workloads, collect reports, and extrapolate to paper scale.
//!
//! All library-kernel runners compile through one process-wide fleet
//! [`PlanCache`] — the fig sweeps re-run the same handful of shapes at
//! many operating points, so each distinct `(kernel, binds, grid,
//! options)` shape compiles exactly once per process.

use crate::csl;
use crate::fleet::PlanCache;
use crate::frontend::{lower_stencil, parse_stencil, stencil_source, StencilKernel};
use crate::kernels;
use crate::machine::{IoDir, MachineConfig, RunReport, Simulator};
use crate::passes::{Options, PassStats};
use crate::sem::{instantiate, Bindings};
use crate::util::SplitMix64;
use anyhow::{anyhow, Result};
use std::sync::OnceLock;

/// The shared harness compilation cache (see module docs). Keyed on
/// pass options too, so `-O0`-vs-`-O2` style sweeps never collide.
fn plan_cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(PlanCache::new)
}

/// WSE-2 full-fabric constants for extrapolation.
pub const PAPER_PES: f64 = 750.0 * 994.0;
pub const FREQ_HZ: f64 = 0.85e9;

/// One measured simulation.
pub struct SimRun {
    pub report: RunReport,
    pub stats: PassStats,
    pub csl_loc: usize,
    pub spada_loc: usize,
}

pub fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_f32()).collect()
}

/// Bind list and grid geometry for one library kernel at scale factor
/// `g` with K-length per-PE vectors: returns `(binds, width, height)`.
/// Thin wrapper over the kernel registry ([`kernels::spec`] →
/// [`kernels::KernelSpec::scaled_binds`]) so the single encoding of
/// every kernel's meta-parameters — dense grid recipes and sparse
/// matrix-shaped binds alike — lives in one place. GEMV variants use
/// `n = 2g` (2×2 blocks per PE); sparse kernels derive CSR extents
/// from the seeded demo problem.
pub fn scaled_binds(
    kernel: &str,
    g: i64,
    k: i64,
) -> Result<(Vec<(&'static str, i64)>, i64, i64)> {
    kernels::spec(kernel)?.scaled_binds(g, k)
}

/// Stage the registry workload for `kernel` at `(g, k)`: dense kernels
/// get the seeded noise of [`stage_random_inputs`]; sparse kernels get
/// the matching seeded demo matrix (valid CSR, consistent with the
/// `NNZP` bind that [`scaled_binds`] returned), staged *after* the
/// noise pass so every declared input is populated either way.
pub fn stage_kernel_inputs(
    sim: &mut Simulator,
    kernel: &str,
    g: i64,
    k: i64,
    seed: u64,
) -> Result<()> {
    stage_random_inputs(sim, seed);
    if kernels::spec(kernel)?.sparse {
        crate::sparse::stage_demo(sim, kernel, g, k)?;
    }
    Ok(())
}

/// Stage deterministic noise into every input binding of `sim` — one
/// `SplitMix64` stream consumed in binding order, so two simulators
/// staged with the same seed see byte-identical inputs. Shared by the
/// equivalence/determinism suites (`dsd_batch`, `parallel_equiv`, the
/// cross-thread property) so the workload definition cannot drift
/// between them.
pub fn stage_random_inputs(sim: &mut Simulator, seed: u64) {
    let inputs: Vec<(String, usize)> = sim
        .program()
        .io
        .iter()
        .filter(|b| b.dir == IoDir::In)
        .map(|b| (b.arg.clone(), (b.total_ports * b.elems_per_pe) as usize))
        .collect();
    let mut rng = SplitMix64::new(seed);
    for (arg, len) in inputs {
        let data: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
        sim.set_input(&arg, &data).expect("staging a declared input binding");
    }
}

/// Read back every output argument's raw words (first binding per
/// argument, in binding order) — the bit-exact observable the
/// equivalence suites compare.
pub fn output_words(sim: &Simulator) -> Vec<(String, Vec<u32>)> {
    let mut outs: Vec<(String, Vec<u32>)> = vec![];
    for b in sim.program().io.iter().filter(|b| b.dir == IoDir::Out) {
        if outs.iter().any(|(a, _)| a == &b.arg) {
            continue;
        }
        outs.push((b.arg.clone(), sim.get_output_words(&b.arg).expect("declared output reads")));
    }
    outs
}

/// Compile + run a reduction collective over a `px × py` grid with
/// K-word per-PE vectors. Returns the run and the root output.
pub fn run_reduce(
    kernel: &str,
    px: i64,
    py: i64,
    k: i64,
    opts: &Options,
) -> Result<(SimRun, Vec<f32>)> {
    let cfg = MachineConfig::with_grid(px.max(2), py.max(1));
    let binds: Vec<(&str, i64)> = match kernel {
        "chain_reduce" => vec![("K", k), ("N", px)],
        "tree_reduce" | "two_phase_reduce" => vec![("K", k), ("NX", px), ("NY", py)],
        other => return Err(anyhow!("not a reduce kernel: {other}")),
    };
    let ck = plan_cache().get(kernel, &binds, &cfg, opts).map_err(anyhow::Error::msg)?;
    let spada_loc = kernels::spada_loc(kernel)?;
    let pes = if kernel == "chain_reduce" { px } else { px * py };
    let mut sim = ck.simulator()?;
    let data = rand_vec(0xF16, (k * pes) as usize);
    sim.set_input("a_in", &data)?;
    let report = sim.run()?;
    let out = sim.get_output("out")?;
    Ok((SimRun { report, stats: ck.stats.clone(), csl_loc: ck.csl_loc, spada_loc }, out))
}

/// Compile + run the 1-D broadcast.
pub fn run_broadcast(p: i64, k: i64, opts: &Options) -> Result<SimRun> {
    let cfg = MachineConfig::with_grid(p, 1);
    let ck =
        plan_cache().get("broadcast", &[("K", k), ("N", p)], &cfg, opts).map_err(anyhow::Error::msg)?;
    let spada_loc = kernels::spada_loc("broadcast")?;
    let mut sim = ck.simulator()?;
    sim.set_input("a_in", &rand_vec(7, k as usize))?;
    let report = sim.run()?;
    Ok(SimRun { report, stats: ck.stats.clone(), csl_loc: ck.csl_loc, spada_loc })
}

/// Compile a stencil through the GT4Py-style pipeline and run it.
pub struct StencilRun {
    pub run: SimRun,
    pub sk: StencilKernel,
    /// f32 outputs by argument name.
    pub outputs: Vec<(String, Vec<f32>)>,
}

pub fn compile_stencil(
    name: &str,
    nx: i64,
    ny: i64,
    k: i64,
    opts: &Options,
) -> Result<(StencilKernel, crate::machine::MachineProgram, PassStats, usize)> {
    let src = stencil_source(name).ok_or_else(|| anyhow!("unknown stencil {name}"))?;
    let ir = parse_stencil(src).map_err(|e| anyhow!("{name}: {e}"))?;
    let sk = lower_stencil(&ir).map_err(|e| anyhow!("{name}: {e}"))?;
    let binds: Bindings =
        [("K", k), ("NX", nx), ("NY", ny)].iter().map(|(s, v)| (s.to_string(), *v)).collect();
    let prog = instantiate(&sk.kernel, &binds).map_err(|e| anyhow!("{name}: {e}"))?;
    let cfg = MachineConfig::with_grid(nx, ny);
    let compiled = csl::compile(&prog, &cfg, opts).map_err(|e| anyhow!("{name}: {e}"))?;
    let loc = compiled.csl_loc();
    Ok((sk, compiled.machine, compiled.stats, loc))
}

pub fn run_stencil(
    name: &str,
    nx: i64,
    ny: i64,
    k: i64,
    opts: &Options,
) -> Result<StencilRun> {
    let (sk, prog, stats, csl_loc) = compile_stencil(name, nx, ny, k, opts)?;
    let spada_loc = crate::spada::pretty::count_loc(&sk.kernel);
    let cfg = MachineConfig::with_grid(nx, ny);
    let mut sim = Simulator::new(cfg, prog)?;
    for (idx, arg) in sk.inputs.iter().enumerate() {
        sim.set_input(arg, &rand_vec(100 + idx as u64, (nx * ny * k) as usize))?;
    }
    let report = sim.run()?;
    let outputs = sk
        .outputs
        .iter()
        .map(|o| Ok((o.clone(), sim.get_output(o)?)))
        .collect::<Result<Vec<_>>>()?;
    Ok(StencilRun { run: SimRun { report, stats, csl_loc, spada_loc }, sk, outputs })
}

/// Compile + run GEMV (square N×N matrix on a `g × g` grid).
pub fn run_gemv(n: i64, g: i64, opts: &Options) -> Result<(SimRun, Vec<f32>, Vec<f32>)> {
    run_gemv_variant("gemv", n, g, opts)
}

/// The GEMV harness inputs: dense matrix, column-major PE blocks
/// (ports i·NY + j), input/initial vectors. Shared by the Fig. 7
/// runners and the `sim_scaling` bench so every consumer stages the
/// same deterministic workload.
pub fn gemv_inputs(n: i64, g: i64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (bm, bn) = ((n / g) as usize, (n / g) as usize);
    let a_dense = rand_vec(21, (n * n) as usize);
    let x = rand_vec(22, n as usize);
    let y0 = rand_vec(23, n as usize);
    let mut a_blocks = vec![0f32; (n * n) as usize];
    let mut off = 0usize;
    for i in 0..g {
        for j in 0..g {
            for c in 0..bn {
                for r in 0..bm {
                    let gr = j as usize * bm + r;
                    let gc = i as usize * bn + c;
                    a_blocks[off + c * bm + r] = a_dense[gr * n as usize + gc];
                }
            }
            off += bm * bn;
        }
    }
    (a_dense, a_blocks, x, y0)
}

/// GEMV with a selectable reduction scheme ("gemv" = pipelined chain,
/// "gemv_tree" = binary tree — the paper's two Fig. 7 variants).
pub fn run_gemv_variant(
    kernel: &str,
    n: i64,
    g: i64,
    opts: &Options,
) -> Result<(SimRun, Vec<f32>, Vec<f32>)> {
    let cfg = MachineConfig::with_grid(g, g);
    let ck = plan_cache()
        .get(kernel, &[("M", n), ("N", n), ("NX", g), ("NY", g)], &cfg, opts)
        .map_err(anyhow::Error::msg)?;
    let spada_loc = kernels::spada_loc(kernel)?;
    let mut sim = ck.simulator()?;
    let (a_dense, a_blocks, x, y0) = gemv_inputs(n, g);
    sim.set_input("a_blk", &a_blocks)?;
    sim.set_input("x_in", &x)?;
    sim.set_input("y_in", &y0)?;
    sim.set_input("alpha", &[1.0])?;
    sim.set_input("beta", &[0.0])?;
    let report = sim.run()?;
    let y = sim.get_output("y_out")?;
    // Dense reference for verification.
    let mut want = vec![0f32; n as usize];
    for r in 0..n as usize {
        want[r] = (0..n as usize).map(|c| a_dense[r * n as usize + c] * x[c]).sum();
    }
    Ok((SimRun { report, stats: ck.stats.clone(), csl_loc: ck.csl_loc, spada_loc }, y, want))
}

/// Extrapolate a measured FLOP rate to the paper's fabric: per-PE work
/// and the nearest-neighbour pipeline depth are scale-invariant, so the
/// rate scales with the PE count.
pub fn extrapolate_floprate(measured: f64, sim_pes: f64) -> f64 {
    measured * (PAPER_PES / sim_pes)
}

/// Harmonic mean of ratios.
pub fn harmonic_mean(v: &[f64]) -> f64 {
    v.len() as f64 / v.iter().map(|x| 1.0 / x).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harmonic() {
        let h = harmonic_mean(&[1.0, 2.0]);
        assert!((h - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reduce_runner_verifies() {
        let (run, out) = run_reduce("tree_reduce", 4, 4, 8, &Options::default()).unwrap();
        assert_eq!(out.len(), 8);
        assert!(run.report.cycles > 0);
    }
}

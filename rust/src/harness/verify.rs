//! Numerical verification: WSE-2 simulator outputs vs the PJRT-executed
//! JAX/Pallas oracles (the three-layer round trip).
//!
//! Shapes must match the artifacts emitted by `python/compile/aot.py`.

use super::common::{rand_vec, run_stencil};
use crate::kernels;
use crate::machine::MachineConfig;
use crate::passes::Options;
use crate::runtime::{max_rel_err, Input, Runtime};
use anyhow::{bail, Result};

const TOL: f32 = 1e-4;

pub fn run() -> Result<()> {
    let rt = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) if !cfg!(feature = "pjrt") => {
            // The stub runtime cannot verify anything: the `pjrt`
            // feature (and `make artifacts`) is optional for the
            // Rust-only build, so skip rather than fail.
            println!("verify skipped: {e:#}");
            return Ok(());
        }
        // A pjrt-enabled build with a broken client is a real failure.
        Err(e) => return Err(e.context("PJRT runtime (did you run `make artifacts`?)")),
    };
    println!("PJRT platform: {}", rt.platform());

    // ---- reduce_16x64: tree reduce on a 16-PE row --------------------
    {
        let (p, k) = (16i64, 64i64);
        let data = rand_vec(1, (p * k) as usize);
        let cfg = MachineConfig::with_grid(p, 1);
        let ck = kernels::compile(
            "tree_reduce",
            &[("K", k), ("NX", p), ("NY", 1)],
            &cfg,
            &Options::default(),
        )?;
        let mut sim = ck.simulator()?;
        sim.set_input("a_in", &data)?;
        sim.run()?;
        let got = sim.get_output("out")?;
        let oracle = rt.load("reduce_16x64")?;
        let want = &oracle.run(&[Input::new(&data, &[p, k])])?[0];
        check("reduce_16x64", &got, want)?;
    }

    // ---- broadcast_16x64 ----------------------------------------------
    {
        let (p, k) = (16i64, 64i64);
        let data = rand_vec(2, k as usize);
        let cfg = MachineConfig::with_grid(p, 1);
        let ck = kernels::compile("broadcast", &[("K", k), ("N", p)], &cfg, &Options::default())?;
        let mut sim = ck.simulator()?;
        sim.set_input("a_in", &data)?;
        sim.run()?;
        let got = sim.get_output("out")?;
        let oracle = rt.load("broadcast_16x64")?;
        let want = &oracle.run(&[Input::new(&data, &[k])])?[0];
        check("broadcast_16x64", &got, want)?;
    }

    // ---- laplacian_16x16x8 ---------------------------------------------
    {
        let (nx, ny, k) = (16i64, 16i64, 8i64);
        let r = run_stencil("laplacian", nx, ny, k, &Options::default())?;
        let input = rand_vec(100, (nx * ny * k) as usize); // seed matches run_stencil
        let oracle = rt.load("laplacian_16x16x8")?;
        let want = &oracle.run(&[Input::new(&input, &[nx, ny, k])])?[0];
        check("laplacian_16x16x8", &r.outputs[0].1, want)?;
    }

    // ---- uvbke_16x16x8 ---------------------------------------------------
    {
        let (nx, ny, k) = (16i64, 16i64, 8i64);
        let r = run_stencil("uvbke", nx, ny, k, &Options::default())?;
        let u = rand_vec(100, (nx * ny * k) as usize);
        let v = rand_vec(101, (nx * ny * k) as usize);
        let oracle = rt.load("uvbke_16x16x8")?;
        let want =
            &oracle.run(&[Input::new(&u, &[nx, ny, k]), Input::new(&v, &[nx, ny, k])])?[0];
        check("uvbke_16x16x8", &r.outputs[0].1, want)?;
    }

    // ---- vertical_8x8x16 --------------------------------------------------
    {
        let (nx, ny, k) = (8i64, 8i64, 16i64);
        let r = run_stencil("vertical", nx, ny, k, &Options::default())?;
        let input = rand_vec(100, (nx * ny * k) as usize);
        let oracle = rt.load("vertical_8x8x16")?;
        let want = &oracle.run(&[Input::new(&input, &[nx, ny, k])])?[0];
        check("vertical_8x8x16", &r.outputs[0].1, want)?;
    }

    // ---- gemv_64x48 ---------------------------------------------------------
    {
        let (m, n, gx, gy) = (64i64, 48i64, 4i64, 4i64);
        let (bm, bn) = ((m / gy) as usize, (n / gx) as usize);
        let cfg = MachineConfig::with_grid(gx, gy);
        let ck = kernels::compile(
            "gemv",
            &[("M", m), ("N", n), ("NX", gx), ("NY", gy)],
            &cfg,
            &Options::default(),
        )?;
        let a = rand_vec(3, (m * n) as usize);
        let x = rand_vec(4, n as usize);
        let y0 = rand_vec(5, m as usize);
        let (alpha, beta) = (1.5f32, -0.5f32);
        let mut blocks = vec![0f32; (m * n) as usize];
        let mut off = 0usize;
        for i in 0..gx {
            for j in 0..gy {
                for c in 0..bn {
                    for r in 0..bm {
                        let gr = j as usize * bm + r;
                        let gc = i as usize * bn + c;
                        blocks[off + c * bm + r] = a[gr * n as usize + gc];
                    }
                }
                off += bm * bn;
            }
        }
        let mut sim = ck.simulator()?;
        sim.set_input("a_blk", &blocks)?;
        sim.set_input("x_in", &x)?;
        sim.set_input("y_in", &y0)?;
        sim.set_input("alpha", &[alpha])?;
        sim.set_input("beta", &[beta])?;
        sim.run()?;
        let got = sim.get_output("y_out")?;
        let oracle = rt.load("gemv_64x48")?;
        let want = &oracle.run(&[
            Input::new(&a, &[m, n]),
            Input::new(&x, &[n]),
            Input::new(&y0, &[m]),
            Input::scalar(&[alpha]),
            Input::scalar(&[beta]),
        ])?[0];
        check("gemv_64x48", &got, want)?;
    }

    println!("all simulator outputs match the PJRT oracles (tol {TOL})");
    Ok(())
}

fn check(name: &str, got: &[f32], want: &[f32]) -> Result<()> {
    if got.len() != want.len() {
        bail!("{name}: length {} vs oracle {}", got.len(), want.len());
    }
    let err = max_rel_err(got, want);
    println!("  {name}: max rel err {err:.2e} over {} elements", got.len());
    if err > TOL {
        bail!("{name}: max rel err {err} exceeds {TOL}");
    }
    Ok(())
}

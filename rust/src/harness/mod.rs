//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation (§VI). One module per artifact; the [`run`]
//! dispatcher is shared by the CLI (`spada bench --exp <id>`) and the
//! cargo benches.
//!
//! Simulations run at scaled-down grids (the simulator is cycle-faithful
//! but this host is not a wafer); each module prints both the measured
//! numbers and the documented extrapolation to the paper's 750×994
//! fabric. EXPERIMENTS.md records paper-vs-measured per artifact.

pub mod common;
pub mod faults;
pub mod fleet;
pub mod table2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod sim_scaling;
pub mod sparse;
pub mod verify;

use anyhow::{bail, Result};

/// All experiment ids.
pub const ALL: &[&str] =
    &["table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "sim", "verify"];

/// Run one experiment (or "all"). `quick` trims sweeps for CI.
pub fn run(exp: &str, quick: bool) -> Result<()> {
    match exp {
        "table2" => table2::run(),
        "fig4" => fig4::run(quick),
        "fig5" => fig5::run(quick),
        "fig6" => fig6::run(quick),
        "fig7" => fig7::run(quick),
        "fig8" => fig8::run(quick),
        "fig9" => fig9::run(quick),
        "sim" => sim_scaling::run(quick),
        // Not part of "all": it overwrites `BENCH_sim.json` with fleet
        // rows, and "all" regenerates the paper artifacts — run it as
        // its own leg (the CI bench job does, after archiving the sim
        // sweep).
        "fleet" => fleet::run(quick),
        // Not part of "all" either: it writes `BENCH_sparse.json` (its
        // own baseline-gated artifact) and is a post-paper extension,
        // not a paper table/figure — the CI sparse leg runs it.
        "sparse" => sparse::run(quick),
        "verify" => verify::run(),
        "all" => {
            for e in ALL {
                println!("\n=== {e} ===");
                run(e, quick)?;
            }
            Ok(())
        }
        other => {
            bail!("unknown experiment {other} (try: {}, fleet, sparse, or all)", ALL.join(", "))
        }
    }
}

//! Sparse workload bench: `spada bench --exp sparse` → `BENCH_sparse.json`.
//!
//! Runs the seeded matrix corpus (one matrix per structural class —
//! uniform, power-law, banded) through all three SpMV dataflow
//! variants *plus* the adaptive selector's pick, and reports
//! **cycles-per-nonzero** and **wavelets-per-nonzero** for each. Two
//! invariants are enforced on every run, not just observed:
//!
//! - every row is produced by an explicit `threads ∈ {1, 4}` sweep
//!   with [`SimOptions`] (the ambient `SPADA_THREADS` is never read),
//!   and the two engines must agree bit-for-bit — so the emitted file
//!   is byte-identical under any `SPADA_THREADS`;
//! - the selector must match the *measured* winner: on every matrix
//!   class, `spmv_auto`'s cycles-per-nonzero must be ≤ the best fixed
//!   variant's, or the bench fails loudly.
//!
//! Rows carry no wall-clock metric (cycles are simulated and
//! deterministic), so `BENCH_sparse.json` is gated by
//! `spada bench --compare` on cycles-per-nonzero, where *lower* is
//! better — see `sim_scaling` for the shared parser/gate.

use crate::bench::Table;
use crate::kernels;
use crate::machine::{MachineConfig, SimOptions};
use crate::passes::Options;
use crate::sparse::{
    self, features, select, spmv_ref, CsrMatrix, Profile, Variant,
};
use anyhow::{anyhow, bail, Result};

pub const OUT_FILE: &str = "BENCH_sparse.json";

/// Corpus geometry: 64×64 matrices on a 4×4 grid — small enough for
/// CI, large enough that the three classes separate decisively.
pub const SIZE: usize = 64;
pub const GRID: usize = 4;

/// The seeded corpus: one matrix per structural class. Quick and full
/// runs use the identical corpus (a matrix is milliseconds of
/// simulation) so baseline row coverage never depends on the mode.
pub fn corpus() -> Vec<(&'static str, Profile, u64)> {
    vec![
        ("uniform", Profile::Uniform { nnz_per_row: 8 }, 0xA11CE),
        ("powerlaw", Profile::PowerLaw { max_row: SIZE }, 0xB0B),
        ("banded", Profile::Banded { half_width: 2 }, 0xC0FFEE),
    ]
}

/// One measured (variant, matrix) cell, identical at 1 and 4 threads.
struct Cell {
    cycles: u64,
    wavelets: u64,
}

/// Compile + stage + run one variant on one matrix at an explicit
/// thread count, verifying the output against the CPU oracle.
fn run_once(
    v: Variant,
    a: &CsrMatrix,
    x: &[f32],
    threads: usize,
) -> Result<(Cell, Vec<(String, Vec<u32>)>)> {
    let staged = sparse::stage(v, a, x, GRID, GRID)?;
    let cfg = MachineConfig::with_grid(GRID as i64, GRID as i64);
    let ck = kernels::compile(v.kernel(), &staged.binds, &cfg, &Options::default())?;
    let mut sim = ck.simulator_with(&SimOptions::default().threads(threads))?;
    staged.apply(&mut sim)?;
    let report = sim.run().map_err(|e| anyhow!("{} threads={threads}: {e}", v.kernel()))?;
    let y = sim.get_output("y_out")?;
    let want = spmv_ref(a, x);
    for (r, (got, exp)) in y.iter().zip(want.iter()).enumerate() {
        if (got - exp).abs() > 1e-3 * (1.0 + exp.abs()) {
            bail!("{} threads={threads}: y[{r}] = {got}, oracle {exp}", v.kernel());
        }
    }
    let outs = super::common::output_words(&sim);
    Ok((Cell { cycles: report.cycles, wavelets: report.metrics.wavelets }, outs))
}

/// Run one variant at threads 1 and 4 and require bit-identity.
fn run_variant(v: Variant, a: &CsrMatrix, x: &[f32]) -> Result<Cell> {
    let (cell1, outs1) = run_once(v, a, x, 1)?;
    let (cell4, outs4) = run_once(v, a, x, 4)?;
    if cell1.cycles != cell4.cycles || cell1.wavelets != cell4.wavelets || outs1 != outs4 {
        bail!("{}: run diverged between 1 and 4 worker threads", v.kernel());
    }
    Ok(cell1)
}

fn json_row(
    kernel: &str,
    class: &str,
    threads: usize,
    nnz: usize,
    cell: &Cell,
    selected: Option<Variant>,
) -> String {
    let cpn = cell.cycles as f64 / nnz as f64;
    let wpn = cell.wavelets as f64 / nnz as f64;
    let sel = match selected {
        Some(v) => format!(", \"selected\": \"{}\"", v.kernel()),
        None => String::new(),
    };
    format!(
        "{{\"kernel\": \"{kernel}:{class}\", \"grid\": \"{g}x{g}\", \"pes\": {p}, \
         \"threads\": {threads}, \"nnz\": {nnz}, \"cycles\": {cy}, \
         \"cycles_per_nnz\": {cpn:.4}, \"wavelets_per_nnz\": {wpn:.4}{sel}}}",
        g = GRID,
        p = GRID * GRID,
        cy = cell.cycles,
    )
}

pub fn run(_quick: bool) -> Result<()> {
    let mut rows: Vec<String> = vec![];
    let mut table = Table::new(&[
        "class", "nnz", "skew", "bandwidth", "variant", "cycles", "cyc/nnz", "wav/nnz", "pick",
    ]);
    let mut failures: Vec<String> = vec![];

    for (class, profile, seed) in corpus() {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let x = sparse::seeded_x(SIZE, seed ^ 0x5EED);
        let f = features(&a);
        let (pick, ests) = select(&a, GRID, GRID);

        let mut cells: Vec<(Variant, Cell)> = vec![];
        for v in Variant::ALL {
            let cell = run_variant(v, &a, &x)?;
            cells.push((v, cell));
        }
        // The adaptive row re-reports the picked variant's measurement
        // (same compile, same staging — the selector only chooses).
        let auto = &cells.iter().find(|(v, _)| *v == pick).unwrap().1;
        let auto_cell = Cell { cycles: auto.cycles, wavelets: auto.wavelets };

        let best = cells.iter().map(|(_, c)| c.cycles).min().unwrap();
        if auto_cell.cycles > best {
            let (bv, _) = cells.iter().find(|(_, c)| c.cycles == best).unwrap();
            failures.push(format!(
                "{class}: selector picked {} ({} cycles) but {} measured {} cycles \
                 (estimates rows/outer/tree = {:?})",
                pick.kernel(),
                auto_cell.cycles,
                bv.kernel(),
                best,
                ests,
            ));
        }

        for (v, cell) in &cells {
            for threads in [1usize, 4] {
                rows.push(json_row(v.kernel(), class, threads, f.nnz, cell, None));
            }
            table.row(&[
                class.to_string(),
                f.nnz.to_string(),
                format!("{:.2}", f.skew),
                f.bandwidth.to_string(),
                v.kernel().to_string(),
                cell.cycles.to_string(),
                format!("{:.3}", cell.cycles as f64 / f.nnz as f64),
                format!("{:.3}", cell.wavelets as f64 / f.nnz as f64),
                if *v == pick { "<- auto".to_string() } else { String::new() },
            ]);
        }
        for threads in [1usize, 4] {
            rows.push(json_row("spmv_auto", class, threads, f.nnz, &auto_cell, Some(pick)));
        }
    }

    table.print();
    let body = format!(
        "{{\n  \"bench\": \"sparse\",\n  \"note\": \"Seeded sparse corpus ({}x{} on a {}x{} \
         grid): all variants + adaptive pick; rows are byte-identical across SPADA_THREADS \
         (explicit 1/4 sweep, no wall-clock fields) and gated on cycles_per_nnz.\",\n  \
         \"runs\": [\n    {}\n  ]\n}}\n",
        SIZE,
        SIZE,
        GRID,
        GRID,
        rows.join(",\n    "),
    );
    std::fs::write(OUT_FILE, &body)?;
    println!("\nwrote {OUT_FILE} ({} rows)", rows.len());

    if !failures.is_empty() {
        bail!("adaptive selector lost to a fixed variant:\n  {}", failures.join("\n  "));
    }
    println!("selector matched the measured winner on every matrix class");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::sim_scaling::parse_bench_json;

    /// Schema pin: sparse rows parse through the shared bench parser
    /// with `events_per_sec` absent and `cycles_per_nnz` present, and
    /// mixed files (dense + sparse rows) parse whole.
    #[test]
    fn sparse_rows_parse_through_the_shared_gate_parser() {
        let cell = Cell { cycles: 712, wavelets: 403 };
        let sparse_row = json_row("spmv_rows", "uniform", 1, 486, &cell, None);
        let auto_row = json_row("spmv_auto", "uniform", 4, 486, &cell, Some(Variant::Rows));
        let dense_row = "{\"kernel\": \"gemv\", \"grid\": \"4x4\", \"pes\": 16, \
                         \"threads\": 1, \"events_per_sec\": 125000.0}";
        let text = format!("{{\"runs\": [\n{sparse_row},\n{auto_row},\n{dense_row}\n]}}");
        let runs = parse_bench_json(&text).unwrap().runs;
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].kernel, "spmv_rows:uniform");
        assert_eq!(runs[0].events_per_sec, None);
        assert!((runs[0].cycles_per_nnz.unwrap() - 712.0 / 486.0).abs() < 1e-3);
        assert_eq!(runs[1].kernel, "spmv_auto:uniform");
        assert_eq!(runs[1].threads, 4);
        assert_eq!(runs[2].events_per_sec, Some(125000.0));
        assert_eq!(runs[2].cycles_per_nnz, None);
    }

    /// The corpus has one matrix per class and stable names — the
    /// baseline gate keys (kernel:class, grid, threads) depend on it.
    #[test]
    fn corpus_classes_are_stable() {
        let names: Vec<&str> = corpus().iter().map(|(c, _, _)| *c).collect();
        assert_eq!(names, ["uniform", "powerlaw", "banded"]);
    }
}

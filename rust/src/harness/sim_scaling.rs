//! `spada bench --exp sim` — reproducible simulator scaling sweep.
//!
//! Runs the six paper kernels across growing fabric sizes (4×4 up to
//! 128×128 in the full sweep; `--quick` stops at 16) and records, per
//! run, the simulated cycle count, host wall time, event count and
//! event-loop throughput. Results are printed as a table and written to
//! `BENCH_sim.json` in the working directory so CI can archive the perf
//! trajectory PR over PR — this is the baseline artifact every future
//! simulator-performance change is measured against.
//!
//! `wall_ms` is **end-to-end** (parse + compile + plan build + I/O
//! staging + simulate), matching what a user of `spada run` pays. At
//! the small grids compile time dominates; the large-grid rows are the
//! ones to read for event-loop throughput, and compiler-side changes
//! will move the small-grid rows — compare like with like.

use super::common::{run_broadcast, run_gemv_variant, run_reduce};
use crate::bench::{eng, Table};
use crate::machine::RunReport;
use crate::passes::Options;
use anyhow::{Context, Result};
use std::time::Instant;

/// Output file, relative to the working directory.
pub const OUT_FILE: &str = "BENCH_sim.json";

/// One measured (kernel, grid) point.
pub struct ScalePoint {
    pub kernel: &'static str,
    pub grid: String,
    pub pes: i64,
    pub cycles: u64,
    pub events: u64,
    pub wavelets: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
}

impl ScalePoint {
    fn of(kernel: &'static str, grid: String, pes: i64, report: &RunReport, wall_s: f64) -> Self {
        ScalePoint {
            kernel,
            grid,
            pes,
            cycles: report.cycles,
            events: report.metrics.events,
            wavelets: report.metrics.wavelets,
            wall_ms: wall_s * 1e3,
            events_per_sec: report.events_per_sec(wall_s),
        }
    }
}

/// The sweep itself (separated from [`run`] so tests can exercise it
/// without touching the filesystem).
pub fn sweep(quick: bool) -> Result<Vec<ScalePoint>> {
    let opts = Options::default();
    let grids: &[i64] = if quick { &[4, 8, 16] } else { &[4, 8, 16, 32, 64, 128] };
    let k = 64i64;
    let mut points = vec![];
    for &g in grids {
        {
            let t0 = Instant::now();
            let (run, _) = run_reduce("chain_reduce", g, 1, k, &opts)
                .with_context(|| format!("chain_reduce {g}x1"))?;
            points.push(ScalePoint::of(
                "chain_reduce",
                format!("{g}x1"),
                g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        {
            let t0 = Instant::now();
            let run = run_broadcast(g, k, &opts).with_context(|| format!("broadcast {g}x1"))?;
            points.push(ScalePoint::of(
                "broadcast",
                format!("{g}x1"),
                g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        for kernel in ["tree_reduce", "two_phase_reduce"] {
            let t0 = Instant::now();
            let (run, _) =
                run_reduce(kernel, g, g, k, &opts).with_context(|| format!("{kernel} {g}x{g}"))?;
            points.push(ScalePoint::of(
                kernel,
                format!("{g}x{g}"),
                g * g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        for kernel in ["gemv", "gemv_tree"] {
            let t0 = Instant::now();
            let n = 2 * g; // 2×2 blocks per PE keeps the sweep tractable
            let (run, _, _) = run_gemv_variant(kernel, n, g, &opts)
                .with_context(|| format!("{kernel} {g}x{g}"))?;
            points.push(ScalePoint::of(
                kernel,
                format!("{g}x{g}"),
                g * g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
    }
    Ok(points)
}

fn json_of(points: &[ScalePoint], quick: bool) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"sim_scaling\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"runs\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"grid\": \"{}\", \"pes\": {}, \"cycles\": {}, \
             \"events\": {}, \"wavelets\": {}, \"wall_ms\": {:.3}, \"events_per_sec\": {:.1}}}{}\n",
            p.kernel,
            p.grid,
            p.pes,
            p.cycles,
            p.events,
            p.wavelets,
            p.wall_ms,
            p.events_per_sec,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn run(quick: bool) -> Result<()> {
    let points = sweep(quick)?;
    let mut table = Table::new(&["kernel", "grid", "PEs", "cycles", "events", "wall ms", "events/s"]);
    for p in &points {
        table.row(&[
            p.kernel.to_string(),
            p.grid.clone(),
            p.pes.to_string(),
            p.cycles.to_string(),
            p.events.to_string(),
            format!("{:.1}", p.wall_ms),
            eng(p.events_per_sec),
        ]);
    }
    table.print();
    std::fs::write(OUT_FILE, json_of(&points, quick)).context(OUT_FILE)?;
    println!("wrote {OUT_FILE} ({} runs)", points.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_covers_all_kernels() {
        let points = sweep(true).unwrap();
        // 3 grids × 6 kernels.
        assert_eq!(points.len(), 18);
        for p in &points {
            assert!(p.cycles > 0, "{} {} ran zero cycles", p.kernel, p.grid);
            assert!(p.events > 0, "{} {} processed zero events", p.kernel, p.grid);
        }
        let json = json_of(&points, true);
        assert!(json.contains("\"bench\": \"sim_scaling\""));
        assert!(json.contains("\"kernel\": \"gemv_tree\""));
    }
}

//! `spada bench --exp sim` — reproducible simulator scaling sweep.
//!
//! Runs the six paper kernels across growing fabric sizes (4×4 up to
//! 128×128 in the full sweep; `--quick` stops at 16) and records, per
//! run, the simulated cycle count, host wall time, event count and
//! event-loop throughput. Results are printed as a table and written to
//! `BENCH_sim.json` in the working directory so CI can archive the perf
//! trajectory PR over PR — this is the baseline artifact every future
//! simulator-performance change is measured against.
//!
//! `wall_ms` is **end-to-end** (parse + compile + plan build + I/O
//! staging + simulate), matching what a user of `spada run` pays. At
//! the small grids compile time dominates; the large-grid rows are the
//! ones to read for event-loop throughput, and compiler-side changes
//! will move the small-grid rows — compare like with like.

use super::common::{run_broadcast, run_gemv_variant, run_reduce};
use crate::bench::{eng, Table};
use crate::machine::RunReport;
use crate::passes::Options;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Output file, relative to the working directory.
pub const OUT_FILE: &str = "BENCH_sim.json";

/// One measured (kernel, grid) point.
pub struct ScalePoint {
    pub kernel: &'static str,
    pub grid: String,
    pub pes: i64,
    pub cycles: u64,
    pub events: u64,
    pub wavelets: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
}

impl ScalePoint {
    fn of(kernel: &'static str, grid: String, pes: i64, report: &RunReport, wall_s: f64) -> Self {
        ScalePoint {
            kernel,
            grid,
            pes,
            cycles: report.cycles,
            events: report.metrics.events,
            wavelets: report.metrics.wavelets,
            wall_ms: wall_s * 1e3,
            events_per_sec: report.events_per_sec(wall_s),
        }
    }
}

/// The sweep itself (separated from [`run`] so tests can exercise it
/// without touching the filesystem).
pub fn sweep(quick: bool) -> Result<Vec<ScalePoint>> {
    let opts = Options::default();
    let grids: &[i64] = if quick { &[4, 8, 16] } else { &[4, 8, 16, 32, 64, 128] };
    let k = 64i64;
    let mut points = vec![];
    for &g in grids {
        {
            let t0 = Instant::now();
            let (run, _) = run_reduce("chain_reduce", g, 1, k, &opts)
                .with_context(|| format!("chain_reduce {g}x1"))?;
            points.push(ScalePoint::of(
                "chain_reduce",
                format!("{g}x1"),
                g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        {
            let t0 = Instant::now();
            let run = run_broadcast(g, k, &opts).with_context(|| format!("broadcast {g}x1"))?;
            points.push(ScalePoint::of(
                "broadcast",
                format!("{g}x1"),
                g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        for kernel in ["tree_reduce", "two_phase_reduce"] {
            let t0 = Instant::now();
            let (run, _) =
                run_reduce(kernel, g, g, k, &opts).with_context(|| format!("{kernel} {g}x{g}"))?;
            points.push(ScalePoint::of(
                kernel,
                format!("{g}x{g}"),
                g * g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
        for kernel in ["gemv", "gemv_tree"] {
            let t0 = Instant::now();
            let n = 2 * g; // 2×2 blocks per PE keeps the sweep tractable
            let (run, _, _) = run_gemv_variant(kernel, n, g, &opts)
                .with_context(|| format!("{kernel} {g}x{g}"))?;
            points.push(ScalePoint::of(
                kernel,
                format!("{g}x{g}"),
                g * g,
                &run.report,
                t0.elapsed().as_secs_f64(),
            ));
        }
    }
    Ok(points)
}

fn json_of(points: &[ScalePoint], quick: bool) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"sim_scaling\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"runs\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"grid\": \"{}\", \"pes\": {}, \"cycles\": {}, \
             \"events\": {}, \"wavelets\": {}, \"wall_ms\": {:.3}, \"events_per_sec\": {:.1}}}{}\n",
            p.kernel,
            p.grid,
            p.pes,
            p.cycles,
            p.events,
            p.wavelets,
            p.wall_ms,
            p.events_per_sec,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn run(quick: bool) -> Result<()> {
    let points = sweep(quick)?;
    let mut table = Table::new(&["kernel", "grid", "PEs", "cycles", "events", "wall ms", "events/s"]);
    for p in &points {
        table.row(&[
            p.kernel.to_string(),
            p.grid.clone(),
            p.pes.to_string(),
            p.cycles.to_string(),
            p.events.to_string(),
            format!("{:.1}", p.wall_ms),
            eng(p.events_per_sec),
        ]);
    }
    table.print();
    std::fs::write(OUT_FILE, json_of(&points, quick)).context(OUT_FILE)?;
    println!("wrote {OUT_FILE} ({} runs)", points.len());
    Ok(())
}

// ---------------------------------------------------------------------
// Bench-regression gate (`spada bench --compare <baseline>`)
// ---------------------------------------------------------------------

/// One parsed run row from a `BENCH_sim.json`-format file.
#[derive(Clone, Debug)]
pub struct BenchRun {
    pub kernel: String,
    pub grid: String,
    pub events_per_sec: f64,
}

/// A parsed bench file.
#[derive(Clone, Debug)]
pub struct BenchFile {
    /// Committed-but-unblessed baselines set `"placeholder": true`; the
    /// gate reports and passes instead of comparing against fiction.
    pub placeholder: bool,
    pub runs: Vec<BenchRun>,
}

fn extract_str(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\": \"");
    let start = line.find(&tag)? + tag.len();
    let end = line[start..].find('"')?;
    Some(line[start..start + end].to_string())
}

fn extract_num(line: &str, key: &str) -> Option<f64> {
    fn numeric(c: char) -> bool {
        c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')
    }
    let tag = format!("\"{key}\": ");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let end = rest.find(|c: char| !numeric(c)).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse the line-oriented JSON `json_of` emits (one run object per
/// line). Deliberately tolerant: any line carrying a `"kernel"` field
/// is a run row; everything else is metadata.
pub fn parse_bench_json(text: &str) -> Result<BenchFile> {
    let placeholder = text.contains("\"placeholder\": true");
    let mut runs = vec![];
    for line in text.lines() {
        if !line.contains("\"kernel\"") {
            continue;
        }
        let kernel = extract_str(line, "kernel")
            .ok_or_else(|| anyhow!("bad run row (no kernel): {line}"))?;
        let grid =
            extract_str(line, "grid").ok_or_else(|| anyhow!("bad run row (no grid): {line}"))?;
        let events_per_sec = extract_num(line, "events_per_sec")
            .ok_or_else(|| anyhow!("bad run row (no events_per_sec): {line}"))?;
        runs.push(BenchRun { kernel, grid, events_per_sec });
    }
    if runs.is_empty() {
        bail!("no bench runs found (not a BENCH_sim.json-format file?)");
    }
    Ok(BenchFile { placeholder, runs })
}

/// Per-kernel comparison outcome (geometric-mean events/s over the
/// grids present in both files).
#[derive(Clone, Debug)]
pub struct KernelDelta {
    pub kernel: String,
    pub matched_runs: usize,
    pub base_eps: f64,
    pub cur_eps: f64,
    /// Relative change: `cur/base - 1` (negative = regression).
    pub delta: f64,
}

fn geomean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Baseline (kernel, grid) rows with no counterpart in the current
/// file. A non-empty result fails the gate: a kernel silently dropped
/// from the sweep must not read as "no regression".
pub fn missing_rows(base: &BenchFile, cur: &BenchFile) -> Vec<String> {
    let have: std::collections::BTreeSet<(&str, &str)> =
        cur.runs.iter().map(|r| (r.kernel.as_str(), r.grid.as_str())).collect();
    base.runs
        .iter()
        .filter(|r| !have.contains(&(r.kernel.as_str(), r.grid.as_str())))
        .map(|r| format!("{} {}", r.kernel, r.grid))
        .collect()
}

/// Compare two bench files per kernel. Pure (no I/O, no printing) so
/// the gate logic is unit-testable.
pub fn compare_runs(base: &BenchFile, cur: &BenchFile) -> Vec<KernelDelta> {
    let mut base_by: BTreeMap<(&str, &str), f64> = BTreeMap::new();
    for r in &base.runs {
        base_by.insert((r.kernel.as_str(), r.grid.as_str()), r.events_per_sec);
    }
    let mut per_kernel: BTreeMap<&str, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in &cur.runs {
        if let Some(&b) = base_by.get(&(r.kernel.as_str(), r.grid.as_str())) {
            let e = per_kernel.entry(r.kernel.as_str()).or_default();
            e.0.push(b);
            e.1.push(r.events_per_sec);
        }
    }
    per_kernel
        .into_iter()
        .map(|(kernel, (b, c))| {
            let (base_eps, cur_eps) = (geomean(&b), geomean(&c));
            KernelDelta {
                kernel: kernel.to_string(),
                matched_runs: b.len(),
                base_eps,
                cur_eps,
                delta: if base_eps > 0.0 { cur_eps / base_eps - 1.0 } else { 0.0 },
            }
        })
        .collect()
}

/// The CLI gate: parse both files, print the per-kernel delta table,
/// and fail (`Err`) if any kernel's events/s dropped more than
/// `threshold` (0.25 = 25%) below the baseline. A placeholder baseline
/// passes with a notice — see ROADMAP.md for the blessing procedure.
pub fn compare_files(baseline_path: &str, current_path: &str, threshold: f64) -> Result<()> {
    let base_text = std::fs::read_to_string(baseline_path).context(baseline_path.to_string())?;
    let base = parse_bench_json(&base_text).context(baseline_path.to_string())?;
    let cur_text = std::fs::read_to_string(current_path).context(current_path.to_string())?;
    let cur = parse_bench_json(&cur_text).context(current_path.to_string())?;
    if base.placeholder {
        println!(
            "bench gate: baseline {baseline_path} is a placeholder (never blessed on this \
             hardware); skipping the comparison. Bless it by copying a real {OUT_FILE} over \
             it — see ROADMAP.md \"Performance\"."
        );
        return Ok(());
    }
    let deltas = compare_runs(&base, &cur);
    if deltas.is_empty() {
        bail!("bench gate: no (kernel, grid) rows in common between baseline and current");
    }
    let missing = missing_rows(&base, &cur);
    if !missing.is_empty() {
        bail!(
            "bench gate: {} baseline row(s) missing from the current sweep ({}); a dropped \
             kernel is not a passing kernel — re-bless {baseline_path} if this is intended",
            missing.len(),
            missing.join(", ")
        );
    }
    let mut table =
        Table::new(&["kernel", "runs", "base events/s", "now events/s", "delta", "verdict"]);
    let mut regressed: Vec<String> = vec![];
    for d in &deltas {
        let fail = d.delta < -threshold;
        table.row(&[
            d.kernel.clone(),
            d.matched_runs.to_string(),
            eng(d.base_eps),
            eng(d.cur_eps),
            format!("{:+.1}%", 100.0 * d.delta),
            if fail { "REGRESSED".into() } else { "ok".into() },
        ]);
        if fail {
            regressed.push(format!("{} ({:+.1}%)", d.kernel, 100.0 * d.delta));
        }
    }
    table.print();
    if !regressed.is_empty() {
        bail!(
            "bench regression beyond {:.0}% on: {} (baseline {baseline_path})",
            100.0 * threshold,
            regressed.join(", ")
        );
    }
    println!(
        "bench gate: {} kernel(s) within {:.0}% of {baseline_path}",
        deltas.len(),
        100.0 * threshold
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_covers_all_kernels() {
        let points = sweep(true).unwrap();
        // 3 grids × 6 kernels.
        assert_eq!(points.len(), 18);
        for p in &points {
            assert!(p.cycles > 0, "{} {} ran zero cycles", p.kernel, p.grid);
            assert!(p.events > 0, "{} {} processed zero events", p.kernel, p.grid);
        }
        let json = json_of(&points, true);
        assert!(json.contains("\"bench\": \"sim_scaling\""));
        assert!(json.contains("\"kernel\": \"gemv_tree\""));

        // The gate's parser must round-trip the writer's format.
        let parsed = parse_bench_json(&json).unwrap();
        assert!(!parsed.placeholder);
        assert_eq!(parsed.runs.len(), points.len());
        for (r, p) in parsed.runs.iter().zip(&points) {
            assert_eq!(r.kernel, p.kernel);
            assert_eq!(r.grid, p.grid);
            assert!((r.events_per_sec - p.events_per_sec).abs() <= 0.06 * (1.0 + p.events_per_sec));
        }
    }

    fn file(rows: &[(&str, &str, f64)], placeholder: bool) -> BenchFile {
        BenchFile {
            placeholder,
            runs: rows
                .iter()
                .map(|(k, g, e)| BenchRun {
                    kernel: k.to_string(),
                    grid: g.to_string(),
                    events_per_sec: *e,
                })
                .collect(),
        }
    }

    #[test]
    fn compare_flags_only_kernels_beyond_threshold() {
        let base = file(
            &[("gemv", "8x8", 1000.0), ("gemv", "16x16", 2000.0), ("broadcast", "8x1", 500.0)],
            false,
        );
        // gemv halves (≈ −50%), broadcast improves.
        let cur = file(
            &[("gemv", "8x8", 500.0), ("gemv", "16x16", 1000.0), ("broadcast", "8x1", 900.0)],
            false,
        );
        let deltas = compare_runs(&base, &cur);
        assert_eq!(deltas.len(), 2);
        let gemv = deltas.iter().find(|d| d.kernel == "gemv").unwrap();
        assert_eq!(gemv.matched_runs, 2);
        assert!((gemv.delta + 0.5).abs() < 1e-9, "{gemv:?}");
        assert!(gemv.delta < -0.25, "a 2x slowdown must trip the 25% gate");
        let bc = deltas.iter().find(|d| d.kernel == "broadcast").unwrap();
        assert!(bc.delta > 0.0);
        // Unmatched rows are never compared against garbage, and rows
        // that vanish from the current sweep are reported as missing.
        let sparse = file(&[("gemv", "64x64", 1.0)], false);
        assert!(compare_runs(&base, &sparse).is_empty());
        let missing = missing_rows(&base, &sparse);
        assert_eq!(missing.len(), 3, "{missing:?}");
        assert!(missing.contains(&"broadcast 8x1".to_string()));
        assert!(missing_rows(&base, &base).is_empty());
    }

    #[test]
    fn parser_detects_placeholder_and_rejects_junk() {
        let text = "{\n  \"placeholder\": true,\n  \"runs\": [\n    {\"kernel\": \"gemv\", \
                    \"grid\": \"4x4\", \"events_per_sec\": 123.4}\n  ]\n}\n";
        let f = parse_bench_json(text).unwrap();
        assert!(f.placeholder);
        assert_eq!(f.runs.len(), 1);
        assert!((f.runs[0].events_per_sec - 123.4).abs() < 1e-9);
        assert!(parse_bench_json("{}").is_err());
    }
}

//! `spada bench --exp sim` — reproducible simulator scaling sweep.
//!
//! Runs the six dense paper kernels ([`crate::kernels::dense_names`])
//! across growing fabric sizes (4×4 up to
//! 128×128 in the full sweep; `--quick` stops at 16) at every worker
//! thread count in [`THREAD_COUNTS`], and records, per run, the
//! simulated cycle count, host wall time, event count, event-loop
//! throughput, and the buffer-model observables (peak endpoint queue
//! depth — the value to size `SPADA_BUF_CAP` from — and backpressure
//! stall cycles), plus the parallel-engine introspection figures from
//! [`spada::machine::EngineStats`]: epoch count, max/mean per-shard
//! event imbalance, and coordinator barrier-wait time (all trivially
//! 0 / 1.0 / 0 on the 1-thread classic-engine rows). Results are
//! printed as a table and written to
//! `BENCH_sim.json` in the working directory so CI can archive the perf
//! trajectory PR over PR — this is the baseline artifact every future
//! simulator-performance change is measured against.
//!
//! Each (kernel, grid) point compiles **once** through the fleet
//! [`PlanCache`] and builds a fresh simulator per thread count with
//! explicit [`SimOptions`] (the sweep never reads the environment, so
//! `BENCH_sim.json` is comparable across CI env legs); timing starts
//! after staging, so `wall_ms` is the simulate-only time. The 1-thread
//! rows are the classic event loop; higher counts run the
//! epoch-parallel engine — cycles/events/wavelets are bit-identical
//! across rows of one point by construction, only `wall_ms` /
//! `events_per_sec` move.

use super::common::{gemv_inputs, rand_vec, scaled_binds};
use crate::bench::{eng, Table};
use crate::fleet::PlanCache;
use crate::machine::{MachineConfig, SimOptions, Simulator};
use crate::passes::Options;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Output file, relative to the working directory.
pub const OUT_FILE: &str = "BENCH_sim.json";

/// Worker-thread counts every sweep point is measured at. Fixed (not
/// host-derived) so `BENCH_sim.json` files from different machines
/// have comparable row sets and the `--compare` gate always finds
/// matching thread counts.
pub const THREAD_COUNTS: &[usize] = &[1, 4];

/// One measured (kernel, grid, threads) point.
pub struct ScalePoint {
    pub kernel: &'static str,
    pub grid: String,
    pub pes: i64,
    /// Simulator worker threads for this run.
    pub threads: usize,
    pub cycles: u64,
    pub events: u64,
    pub wavelets: u64,
    /// Peak (PE, color) endpoint queue depth in words — the value to
    /// size `SPADA_BUF_CAP` from for this point.
    pub peak_queue_depth: u64,
    /// Backpressure stall cycles (0 unless a finite buffer capacity is
    /// configured for the sweep).
    pub stall_cycles: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
    /// Epoch-parallel engine epochs executed (0 on 1-thread rows — the
    /// classic event loop has no epochs).
    pub epochs: u64,
    /// Max/mean per-shard event ratio (1.0 = perfectly balanced, and by
    /// convention for the 1-shard classic engine). The headroom figure
    /// for the shard-balancing lever in ROADMAP.md.
    pub shard_imbalance: f64,
    /// Host milliseconds the coordinator spent blocked on epoch
    /// barriers — the serialized straggler-bound fraction of the run.
    pub barrier_wait_ms: f64,
}

/// Stage one sweep kernel's deterministic inputs. Preserves the
/// historical per-argument seeds of the figure runners, so the sweep's
/// simulated observables stay comparable across snapshots.
fn stage_inputs(sim: &mut Simulator, kernel: &str, g: i64, k: i64) -> Result<()> {
    match kernel {
        "chain_reduce" => sim.set_input("a_in", &rand_vec(0xF16, (k * g) as usize))?,
        "broadcast" => sim.set_input("a_in", &rand_vec(7, k as usize))?,
        "tree_reduce" | "two_phase_reduce" => {
            sim.set_input("a_in", &rand_vec(0xF16, (k * g * g) as usize))?
        }
        _ => {
            let n = 2 * g; // 2×2 blocks per PE keeps the sweep tractable
            let (_, a_blocks, x, y0) = gemv_inputs(n, g);
            sim.set_input("a_blk", &a_blocks)?;
            sim.set_input("x_in", &x)?;
            sim.set_input("y_in", &y0)?;
            sim.set_input("alpha", &[1.0])?;
            sim.set_input("beta", &[0.0])?;
        }
    }
    Ok(())
}

/// The sweep itself (separated from [`run`] so tests can exercise it
/// without touching the filesystem).
pub fn sweep(quick: bool) -> Result<Vec<ScalePoint>> {
    let opts = Options::default();
    let cache = PlanCache::new();
    let grids: &[i64] = if quick { &[4, 8, 16] } else { &[4, 8, 16, 32, 64, 128] };
    let k = 64i64;
    // The dense-regular subset only: sparse kernels have their own
    // sweep (`--exp sparse`) with matrix-shaped workloads and
    // per-nonzero metrics, and adding them here would silently change
    // every blessed `BENCH_sim.json` row set.
    let kernels = crate::kernels::dense_names();
    let mut points = vec![];
    for &g in grids {
        for &kernel in &kernels {
            let (binds, w, h) = scaled_binds(kernel, g, k)?;
            let cfg = MachineConfig::with_grid(w, h);
            let ck = cache
                .get(kernel, &binds, &cfg, &opts)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("{kernel} grid {g}"))?;
            let (grid, pes) =
                if h == 1 { (format!("{g}x1"), g) } else { (format!("{g}x{g}"), g * g) };
            for &threads in THREAD_COUNTS {
                let mut sim = ck
                    .simulator_with(&SimOptions::default().threads(threads))
                    .map_err(anyhow::Error::from)
                    .with_context(|| format!("{kernel} {grid} threads={threads}"))?;
                stage_inputs(&mut sim, kernel, g, k)?;
                let t0 = Instant::now();
                let report = sim
                    .run()
                    .map_err(anyhow::Error::from)
                    .with_context(|| format!("{kernel} {grid} threads={threads}"))?;
                let wall_s = t0.elapsed().as_secs_f64();
                let engine = sim.engine_stats();
                points.push(ScalePoint {
                    kernel,
                    grid: grid.clone(),
                    pes,
                    threads,
                    cycles: report.cycles,
                    events: report.metrics.events,
                    wavelets: report.metrics.wavelets,
                    peak_queue_depth: report.metrics.peak_queue_depth,
                    stall_cycles: report.metrics.stall_cycles,
                    wall_ms: wall_s * 1e3,
                    events_per_sec: report.events_per_sec(wall_s),
                    epochs: engine.epochs,
                    shard_imbalance: engine.imbalance(),
                    barrier_wait_ms: engine.barrier_wait_ns as f64 / 1e6,
                });
            }
        }
    }
    Ok(points)
}

fn json_of(points: &[ScalePoint], quick: bool) -> String {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"sim_scaling\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"runs\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"grid\": \"{}\", \"pes\": {}, \"threads\": {}, \
             \"host_parallelism\": {}, \"cycles\": {}, \"events\": {}, \"wavelets\": {}, \
             \"peak_queue_depth\": {}, \"stall_cycles\": {}, \"wall_ms\": {:.3}, \
             \"events_per_sec\": {:.1}, \"epochs\": {}, \"shard_imbalance\": {:.3}, \
             \"barrier_wait_ms\": {:.3}}}{}\n",
            p.kernel,
            p.grid,
            p.pes,
            p.threads,
            host,
            p.cycles,
            p.events,
            p.wavelets,
            p.peak_queue_depth,
            p.stall_cycles,
            p.wall_ms,
            p.events_per_sec,
            p.epochs,
            p.shard_imbalance,
            p.barrier_wait_ms,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn run(quick: bool) -> Result<()> {
    let points = sweep(quick)?;
    let mut table = Table::new(&[
        "kernel", "grid", "PEs", "thr", "cycles", "events", "peakq", "stalls", "wall ms",
        "events/s", "epochs", "imbal", "barrier ms",
    ]);
    for p in &points {
        table.row(&[
            p.kernel.to_string(),
            p.grid.clone(),
            p.pes.to_string(),
            p.threads.to_string(),
            p.cycles.to_string(),
            p.events.to_string(),
            p.peak_queue_depth.to_string(),
            p.stall_cycles.to_string(),
            format!("{:.1}", p.wall_ms),
            eng(p.events_per_sec),
            p.epochs.to_string(),
            format!("{:.2}", p.shard_imbalance),
            format!("{:.1}", p.barrier_wait_ms),
        ]);
    }
    table.print();
    std::fs::write(OUT_FILE, json_of(&points, quick)).context(OUT_FILE)?;
    println!("wrote {OUT_FILE} ({} runs)", points.len());
    Ok(())
}

// ---------------------------------------------------------------------
// Bench-regression gate (`spada bench --compare <baseline>`)
// ---------------------------------------------------------------------

/// One parsed run row from a `BENCH_sim.json`-format file.
///
/// Only `kernel` and `grid` are required, plus **one** gating metric:
/// `events_per_sec` (dense sweep / fleet rows) or `cycles_per_nnz`
/// (`BENCH_sparse.json` rows). **Everything that arrived later is
/// uniformly optional**: a baseline blessed before a field existed
/// must parse (with `None`) rather than hard-fail the gate, and newer
/// row kinds (the `--exp fleet` rows with `sims_per_sec`, the sparse
/// rows with per-nonzero metrics) must parse with the same code path.
#[derive(Clone, Debug)]
pub struct BenchRun {
    pub kernel: String,
    pub grid: String,
    /// Worker threads the row was measured at (1 when the file predates
    /// the threads field, so old baselines keep comparing 1-vs-1).
    pub threads: usize,
    /// Dense-sweep throughput (absent on sparse rows, which gate on
    /// `cycles_per_nnz` instead).
    pub events_per_sec: Option<f64>,
    /// Buffer-model observables (absent before the finite-buffer PR).
    pub peak_queue_depth: Option<f64>,
    pub stall_cycles: Option<f64>,
    /// Parallel-engine introspection (absent before the epoch-parallel
    /// engine PR).
    pub epochs: Option<f64>,
    pub shard_imbalance: Option<f64>,
    pub barrier_wait_ms: Option<f64>,
    /// Batch-fleet throughput (only on `--exp fleet` rows).
    pub sims_per_sec: Option<f64>,
    /// Sparse-workload fields (only on `BENCH_sparse.json` rows).
    pub nnz: Option<f64>,
    pub cycles_per_nnz: Option<f64>,
    pub wavelets_per_nnz: Option<f64>,
}

impl BenchRun {
    /// The higher-is-better gating score: events/s for dense rows,
    /// inverse cycles-per-nonzero for sparse rows (simulated cycles are
    /// deterministic, so sparse regressions are exact, not noisy). One
    /// scale lets the geomean/delta machinery serve both artifacts;
    /// rows only ever pair with rows of the same (kernel, grid,
    /// threads) key, so the two metrics never mix inside one delta.
    pub fn score(&self) -> Option<f64> {
        self.events_per_sec
            .or_else(|| self.cycles_per_nnz.map(|c| 1.0 / c.max(1e-12)))
    }
}

/// A parsed bench file.
#[derive(Clone, Debug)]
pub struct BenchFile {
    /// Committed-but-unblessed baselines set `"placeholder": true`; the
    /// gate reports and passes instead of comparing against fiction.
    pub placeholder: bool,
    pub runs: Vec<BenchRun>,
}

fn extract_str(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\": \"");
    let start = line.find(&tag)? + tag.len();
    let end = line[start..].find('"')?;
    Some(line[start..start + end].to_string())
}

fn extract_num(line: &str, key: &str) -> Option<f64> {
    fn numeric(c: char) -> bool {
        c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')
    }
    let tag = format!("\"{key}\": ");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let end = rest.find(|c: char| !numeric(c)).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse the line-oriented JSON `json_of` emits (one run object per
/// line). Deliberately tolerant: any line carrying a `"kernel"` field
/// is a run row; everything else is metadata.
pub fn parse_bench_json(text: &str) -> Result<BenchFile> {
    let placeholder = text.contains("\"placeholder\": true");
    let mut runs = vec![];
    for line in text.lines() {
        if !line.contains("\"kernel\"") {
            continue;
        }
        let kernel = extract_str(line, "kernel")
            .ok_or_else(|| anyhow!("bad run row (no kernel): {line}"))?;
        let grid =
            extract_str(line, "grid").ok_or_else(|| anyhow!("bad run row (no grid): {line}"))?;
        let threads = extract_num(line, "threads").map(|t| t as usize).unwrap_or(1);
        let events_per_sec = extract_num(line, "events_per_sec");
        let cycles_per_nnz = extract_num(line, "cycles_per_nnz");
        if events_per_sec.is_none() && cycles_per_nnz.is_none() {
            bail!("bad run row (neither events_per_sec nor cycles_per_nnz): {line}");
        }
        runs.push(BenchRun {
            kernel,
            grid,
            threads,
            events_per_sec,
            peak_queue_depth: extract_num(line, "peak_queue_depth"),
            stall_cycles: extract_num(line, "stall_cycles"),
            epochs: extract_num(line, "epochs"),
            shard_imbalance: extract_num(line, "shard_imbalance"),
            barrier_wait_ms: extract_num(line, "barrier_wait_ms"),
            sims_per_sec: extract_num(line, "sims_per_sec"),
            nnz: extract_num(line, "nnz"),
            cycles_per_nnz,
            wavelets_per_nnz: extract_num(line, "wavelets_per_nnz"),
        });
    }
    if runs.is_empty() {
        bail!("no bench runs found (not a BENCH_sim.json-format file?)");
    }
    Ok(BenchFile { placeholder, runs })
}

/// Per-kernel comparison outcome (geometric-mean [`BenchRun::score`] —
/// events/s, or 1/cycles-per-nonzero on sparse rows — over the (grid,
/// threads) rows present in both files; rows only ever compare against
/// the same thread count, so a 1-thread baseline is never diffed
/// against a parallel run).
#[derive(Clone, Debug)]
pub struct KernelDelta {
    pub kernel: String,
    pub matched_runs: usize,
    pub base_eps: f64,
    pub cur_eps: f64,
    /// Relative change: `cur/base - 1` (negative = regression — a
    /// throughput drop, or equivalently a cycles-per-nonzero rise).
    pub delta: f64,
}

fn geomean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    (v.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / v.len() as f64).exp()
}

/// Baseline (kernel, grid, threads) rows with no counterpart in the
/// current file. A non-empty result fails the gate: a kernel (or a
/// thread count) silently dropped from the sweep must not read as "no
/// regression".
pub fn missing_rows(base: &BenchFile, cur: &BenchFile) -> Vec<String> {
    let have: std::collections::BTreeSet<(&str, &str, usize)> =
        cur.runs.iter().map(|r| (r.kernel.as_str(), r.grid.as_str(), r.threads)).collect();
    base.runs
        .iter()
        .filter(|r| !have.contains(&(r.kernel.as_str(), r.grid.as_str(), r.threads)))
        .map(|r| format!("{} {} threads={}", r.kernel, r.grid, r.threads))
        .collect()
}

/// Compare two bench files per kernel. Pure (no I/O, no printing) so
/// the gate logic is unit-testable. Only rows matching on (kernel,
/// grid, threads) are compared.
pub fn compare_runs(base: &BenchFile, cur: &BenchFile) -> Vec<KernelDelta> {
    let mut base_by: BTreeMap<(&str, &str, usize), f64> = BTreeMap::new();
    for r in &base.runs {
        if let Some(s) = r.score() {
            base_by.insert((r.kernel.as_str(), r.grid.as_str(), r.threads), s);
        }
    }
    let mut per_kernel: BTreeMap<&str, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in &cur.runs {
        let (Some(&b), Some(c)) =
            (base_by.get(&(r.kernel.as_str(), r.grid.as_str(), r.threads)), r.score())
        else {
            continue;
        };
        let e = per_kernel.entry(r.kernel.as_str()).or_default();
        e.0.push(b);
        e.1.push(c);
    }
    per_kernel
        .into_iter()
        .map(|(kernel, (b, c))| {
            let (base_eps, cur_eps) = (geomean(&b), geomean(&c));
            KernelDelta {
                kernel: kernel.to_string(),
                matched_runs: b.len(),
                base_eps,
                cur_eps,
                delta: if base_eps > 0.0 { cur_eps / base_eps - 1.0 } else { 0.0 },
            }
        })
        .collect()
}

/// The CLI gate: parse both files, print the per-kernel delta table,
/// and fail (`Err`) if any kernel's score (events/s, or inverse
/// cycles-per-nonzero for `BENCH_sparse.json` rows) dropped more than
/// `threshold` (0.25 = 25%) below the baseline. A placeholder baseline
/// passes with a notice — see ROADMAP.md for the blessing procedure.
pub fn compare_files(baseline_path: &str, current_path: &str, threshold: f64) -> Result<()> {
    let base_text = std::fs::read_to_string(baseline_path).context(baseline_path.to_string())?;
    let base = parse_bench_json(&base_text).context(baseline_path.to_string())?;
    let cur_text = std::fs::read_to_string(current_path).context(current_path.to_string())?;
    let cur = parse_bench_json(&cur_text).context(current_path.to_string())?;
    if base.placeholder {
        println!(
            "bench gate: baseline {baseline_path} is a placeholder (never blessed on this \
             hardware); skipping the comparison. Bless it by copying a real {OUT_FILE} over \
             it — see ROADMAP.md \"Performance\"."
        );
        return Ok(());
    }
    let deltas = compare_runs(&base, &cur);
    if deltas.is_empty() {
        bail!(
            "bench gate: no (kernel, grid, threads) rows in common between baseline and current"
        );
    }
    let missing = missing_rows(&base, &cur);
    if !missing.is_empty() {
        bail!(
            "bench gate: {} baseline row(s) missing from the current sweep ({}); a dropped \
             kernel is not a passing kernel — re-bless {baseline_path} if this is intended",
            missing.len(),
            missing.join(", ")
        );
    }
    let mut table =
        Table::new(&["kernel", "runs", "base score", "now score", "delta", "verdict"]);
    let mut regressed: Vec<String> = vec![];
    for d in &deltas {
        let fail = d.delta < -threshold;
        table.row(&[
            d.kernel.clone(),
            d.matched_runs.to_string(),
            eng(d.base_eps),
            eng(d.cur_eps),
            format!("{:+.1}%", 100.0 * d.delta),
            if fail { "REGRESSED".into() } else { "ok".into() },
        ]);
        if fail {
            regressed.push(format!("{} ({:+.1}%)", d.kernel, 100.0 * d.delta));
        }
    }
    table.print();
    if !regressed.is_empty() {
        bail!(
            "bench regression beyond {:.0}% on: {} (baseline {baseline_path})",
            100.0 * threshold,
            regressed.join(", ")
        );
    }
    println!(
        "bench gate: {} kernel(s) within {:.0}% of {baseline_path}",
        deltas.len(),
        100.0 * threshold
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_covers_all_kernels_and_thread_counts() {
        let points = sweep(true).unwrap();
        // 3 grids × 6 kernels × |THREAD_COUNTS|.
        assert_eq!(points.len(), 18 * THREAD_COUNTS.len());
        for p in &points {
            assert!(p.cycles > 0, "{} {} ran zero cycles", p.kernel, p.grid);
            assert!(p.events > 0, "{} {} processed zero events", p.kernel, p.grid);
            // Engine introspection: 1-thread rows are the classic
            // engine (no epochs, trivial imbalance); multi-thread rows
            // may still fall back to it when a kernel's links fold into
            // a single island, so only the invariant bound is asserted.
            if p.threads == 1 {
                assert_eq!(p.epochs, 0, "{} {}: classic engine has no epochs", p.kernel, p.grid);
                assert_eq!(p.shard_imbalance, 1.0);
            } else {
                assert!(p.shard_imbalance >= 1.0, "{} {}: {}", p.kernel, p.grid, p.shard_imbalance);
            }
        }
        // At least one sweep kernel decomposes into ≥ 2 islands, so the
        // parallel rows as a whole must have logged epochs.
        assert!(
            points.iter().any(|p| p.threads > 1 && p.epochs > 0),
            "no parallel row ran the epoch engine"
        );
        // Simulated behaviour is thread-count-invariant: rows of one
        // (kernel, grid) point differ only in wall-clock fields.
        let mut by_point: BTreeMap<(&str, &str), Vec<(u64, u64, u64, u64, u64)>> = BTreeMap::new();
        for p in &points {
            by_point.entry((p.kernel, p.grid.as_str())).or_default().push((
                p.cycles,
                p.events,
                p.wavelets,
                p.peak_queue_depth,
                p.stall_cycles,
            ));
        }
        for ((kernel, grid), rows) in &by_point {
            assert_eq!(rows.len(), THREAD_COUNTS.len());
            assert!(
                rows.windows(2).all(|w| w[0] == w[1]),
                "{kernel} {grid}: cycles/events/wavelets diverged across thread counts: {rows:?}"
            );
        }
        let json = json_of(&points, true);
        assert!(json.contains("\"bench\": \"sim_scaling\""));
        assert!(json.contains("\"kernel\": \"gemv_tree\""));
        assert!(json.contains("\"threads\": 1"));
        assert!(json.contains("\"threads\": 4"));
        assert!(json.contains("\"host_parallelism\""));
        assert!(json.contains("\"peak_queue_depth\""));
        assert!(json.contains("\"stall_cycles\""));
        assert!(json.contains("\"epochs\""));
        assert!(json.contains("\"shard_imbalance\""));
        assert!(json.contains("\"barrier_wait_ms\""));

        // The gate's parser must round-trip the writer's format.
        let parsed = parse_bench_json(&json).unwrap();
        assert!(!parsed.placeholder);
        assert_eq!(parsed.runs.len(), points.len());
        for (r, p) in parsed.runs.iter().zip(&points) {
            assert_eq!(r.kernel, p.kernel);
            assert_eq!(r.grid, p.grid);
            assert_eq!(r.threads, p.threads);
            let eps = r.events_per_sec.expect("dense rows always carry events_per_sec");
            assert!((eps - p.events_per_sec).abs() <= 0.06 * (1.0 + p.events_per_sec));
            assert!(r.cycles_per_nnz.is_none(), "dense rows carry no sparse metrics");
        }
    }

    fn file(rows: &[(&str, &str, usize, f64)], placeholder: bool) -> BenchFile {
        BenchFile {
            placeholder,
            runs: rows
                .iter()
                .map(|(k, g, t, e)| BenchRun {
                    kernel: k.to_string(),
                    grid: g.to_string(),
                    threads: *t,
                    events_per_sec: Some(*e),
                    peak_queue_depth: None,
                    stall_cycles: None,
                    epochs: None,
                    shard_imbalance: None,
                    barrier_wait_ms: None,
                    sims_per_sec: None,
                    nnz: None,
                    cycles_per_nnz: None,
                    wavelets_per_nnz: None,
                })
                .collect(),
        }
    }

    #[test]
    fn compare_flags_only_kernels_beyond_threshold() {
        let base = file(
            &[
                ("gemv", "8x8", 1, 1000.0),
                ("gemv", "16x16", 1, 2000.0),
                ("broadcast", "8x1", 1, 500.0),
            ],
            false,
        );
        // gemv halves (≈ −50%), broadcast improves.
        let cur = file(
            &[
                ("gemv", "8x8", 1, 500.0),
                ("gemv", "16x16", 1, 1000.0),
                ("broadcast", "8x1", 1, 900.0),
            ],
            false,
        );
        let deltas = compare_runs(&base, &cur);
        assert_eq!(deltas.len(), 2);
        let gemv = deltas.iter().find(|d| d.kernel == "gemv").unwrap();
        assert_eq!(gemv.matched_runs, 2);
        assert!((gemv.delta + 0.5).abs() < 1e-9, "{gemv:?}");
        assert!(gemv.delta < -0.25, "a 2x slowdown must trip the 25% gate");
        let bc = deltas.iter().find(|d| d.kernel == "broadcast").unwrap();
        assert!(bc.delta > 0.0);
        // Unmatched rows are never compared against garbage, and rows
        // that vanish from the current sweep are reported as missing.
        let sparse = file(&[("gemv", "64x64", 1, 1.0)], false);
        assert!(compare_runs(&base, &sparse).is_empty());
        let missing = missing_rows(&base, &sparse);
        assert_eq!(missing.len(), 3, "{missing:?}");
        assert!(missing.contains(&"broadcast 8x1 threads=1".to_string()));
        assert!(missing_rows(&base, &base).is_empty());
    }

    #[test]
    fn compare_only_matches_rows_with_equal_thread_counts() {
        // Same kernel/grid measured at different thread counts must
        // never be compared against each other: a 1-thread baseline
        // row only matches a 1-thread current row.
        let base = file(&[("gemv", "8x8", 1, 1000.0), ("gemv", "8x8", 4, 3000.0)], false);
        let cur = file(&[("gemv", "8x8", 1, 990.0), ("gemv", "8x8", 4, 2900.0)], false);
        let deltas = compare_runs(&base, &cur);
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].matched_runs, 2);
        assert!(deltas[0].delta.abs() < 0.05, "{:?}", deltas[0]);
        // A current file missing the 4-thread rows fails row coverage
        // (and its 1-thread rows never pair with 4-thread baselines).
        let cur_1t = file(&[("gemv", "8x8", 1, 990.0)], false);
        let deltas = compare_runs(&base, &cur_1t);
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].matched_runs, 1);
        let missing = missing_rows(&base, &cur_1t);
        assert_eq!(missing, vec!["gemv 8x8 threads=4".to_string()]);
    }

    #[test]
    fn parser_detects_placeholder_and_rejects_junk() {
        let text = "{\n  \"placeholder\": true,\n  \"runs\": [\n    {\"kernel\": \"gemv\", \
                    \"grid\": \"4x4\", \"events_per_sec\": 123.4}\n  ]\n}\n";
        let f = parse_bench_json(text).unwrap();
        assert!(f.placeholder);
        assert_eq!(f.runs.len(), 1);
        assert!((f.runs[0].events_per_sec.unwrap() - 123.4).abs() < 1e-9);
        // Rows without a threads field (pre-parallel baselines) parse
        // as 1-thread rows.
        assert_eq!(f.runs[0].threads, 1);
        assert!(parse_bench_json("{}").is_err());
        // A row with neither gating metric is junk, not a silent pass.
        assert!(parse_bench_json(
            "{\"runs\": [\n{\"kernel\": \"gemv\", \"grid\": \"4x4\", \"cycles\": 7}\n]}"
        )
        .is_err());
    }

    #[test]
    fn sparse_rows_gate_on_cycles_per_nnz() {
        let sparse_row = |cpn: f64| BenchRun {
            kernel: "spmv_rows:uniform".to_string(),
            grid: "4x4".to_string(),
            threads: 1,
            events_per_sec: None,
            peak_queue_depth: None,
            stall_cycles: None,
            epochs: None,
            shard_imbalance: None,
            barrier_wait_ms: None,
            sims_per_sec: None,
            nnz: Some(486.0),
            cycles_per_nnz: Some(cpn),
            wavelets_per_nnz: Some(0.83),
        };
        let base = BenchFile { placeholder: false, runs: vec![sparse_row(1.5)] };
        // Cycles-per-nonzero doubles: score halves, the 25% gate trips.
        let cur = BenchFile { placeholder: false, runs: vec![sparse_row(3.0)] };
        let deltas = compare_runs(&base, &cur);
        assert_eq!(deltas.len(), 1);
        assert!((deltas[0].delta + 0.5).abs() < 1e-9, "{:?}", deltas[0]);
        // Getting *faster* (cpn falls) is an improvement, not a trip.
        let better = BenchFile { placeholder: false, runs: vec![sparse_row(1.0)] };
        let deltas = compare_runs(&base, &better);
        assert!(deltas[0].delta > 0.0, "{:?}", deltas[0]);
        assert!(missing_rows(&base, &cur).is_empty());
    }

    #[test]
    fn post_pr4_fields_are_uniformly_optional() {
        // An old baseline row — nothing beyond the original triple —
        // must parse with every later field None, never hard-fail.
        let old = "{\"runs\": [\n    {\"kernel\": \"gemv\", \"grid\": \"8x8\", \
                   \"events_per_sec\": 10.0}\n]}";
        let f = parse_bench_json(old).unwrap();
        let r = &f.runs[0];
        assert!(r.peak_queue_depth.is_none() && r.stall_cycles.is_none());
        assert!(r.epochs.is_none() && r.shard_imbalance.is_none());
        assert!(r.barrier_wait_ms.is_none() && r.sims_per_sec.is_none());
        assert!(r.nnz.is_none() && r.cycles_per_nnz.is_none() && r.wavelets_per_nnz.is_none());
        // A current sweep row fills the engine fields; a fleet row
        // fills sims_per_sec — the same parser reads all three ages.
        let new = "{\"runs\": [\n    {\"kernel\": \"gemv\", \"grid\": \"8x8\", \"threads\": 4, \
                   \"events_per_sec\": 10.0, \"peak_queue_depth\": 3, \"stall_cycles\": 0, \
                   \"epochs\": 7, \"shard_imbalance\": 1.250, \"barrier_wait_ms\": 0.021}\n    \
                   {\"kernel\": \"fleet_mixed\", \"grid\": \"batch\", \"threads\": 4, \
                   \"events_per_sec\": 5.0, \"sims_per_sec\": 120.5, \"jobs\": 26}\n]}";
        let f = parse_bench_json(new).unwrap();
        assert_eq!(f.runs.len(), 2);
        assert_eq!(f.runs[0].epochs, Some(7.0));
        assert_eq!(f.runs[0].shard_imbalance, Some(1.25));
        assert_eq!(f.runs[0].barrier_wait_ms, Some(0.021));
        assert_eq!(f.runs[0].peak_queue_depth, Some(3.0));
        assert!(f.runs[0].sims_per_sec.is_none());
        assert_eq!(f.runs[1].sims_per_sec, Some(120.5));
        // Old and new rows interoperate in one comparison.
        let deltas = compare_runs(&f, &f);
        assert!(!deltas.is_empty());
    }
}

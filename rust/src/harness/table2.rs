//! Table II: lines of code across representations.
//!
//! Columns mirror the paper: GT4Py (stencil-DSL source), SpaDA
//! (canonical pretty-printed kernel), generated CSL (all code files +
//! layout + host script), and the CSL/Source expansion ratio with its
//! harmonic mean.

use super::common::harmonic_mean;
use crate::bench::Table;
use crate::frontend::{lower_stencil, parse_stencil, stencil_source};
use crate::kernels;
use crate::machine::MachineConfig;
use crate::passes::Options;
use crate::sem::{instantiate, Bindings};
use anyhow::Result;

/// Reference instantiations (scaled; the paper compiled at wafer scale,
/// where the per-PE layout lines dominate even more).
fn collective_rows() -> Vec<(&'static str, Vec<(&'static str, i64)>, (i64, i64))> {
    vec![
        ("broadcast", vec![("K", 256), ("N", 64)], (64, 1)),
        ("chain_reduce", vec![("K", 256), ("N", 64)], (64, 1)),
        ("tree_reduce", vec![("K", 256), ("NX", 32), ("NY", 32)], (32, 32)),
        ("two_phase_reduce", vec![("K", 256), ("NX", 32), ("NY", 32)], (32, 32)),
        ("gemv", vec![("M", 512), ("N", 512), ("NX", 16), ("NY", 16)], (16, 16)),
        ("gemv_tree", vec![("M", 512), ("N", 512), ("NX", 16), ("NY", 16)], (16, 16)),
    ]
}

pub fn run() -> Result<()> {
    let mut table = Table::new(&["Kernel", "GT4Py", "SpaDA", "CSL", "CSL/Source"]);
    let mut ratios = vec![];

    for (name, binds, (w, h)) in collective_rows() {
        let cfg = MachineConfig::with_grid(w, h);
        let csl_loc = kernels::compile(name, &binds, &cfg, &Options::default())?.csl_loc;
        let spada = kernels::spada_loc(name)?;
        let ratio = csl_loc as f64 / spada as f64;
        ratios.push(ratio);
        table.row(&[
            name.to_string(),
            "-".into(),
            spada.to_string(),
            csl_loc.to_string(),
            format!("{ratio:.2}x"),
        ]);
    }

    for (name, nx, ny, k) in
        [("vertical", 8i64, 8i64, 16i64), ("laplacian", 16, 16, 8), ("uvbke", 16, 16, 8)]
    {
        let src = stencil_source(name).unwrap();
        let gt_loc = src.lines().filter(|l| {
            let t = l.trim();
            !t.is_empty() && !t.starts_with("//")
        }).count();
        let ir = parse_stencil(src).map_err(anyhow::Error::msg)?;
        let sk = lower_stencil(&ir).map_err(anyhow::Error::msg)?;
        let spada = crate::spada::pretty::count_loc(&sk.kernel);
        let binds: Bindings =
            [("K", k), ("NX", nx), ("NY", ny)].iter().map(|(s, v)| (s.to_string(), *v)).collect();
        let prog = instantiate(&sk.kernel, &binds).map_err(anyhow::Error::msg)?;
        let cfg = MachineConfig::with_grid(nx, ny);
        let compiled =
            crate::csl::compile(&prog, &cfg, &Options::default()).map_err(anyhow::Error::msg)?;
        let csl_loc = compiled.csl_loc();
        // The ratio for stencils is CSL / GT4Py source (the paper's
        // "616×" story): the DSL user never sees the SpaDA.
        let ratio = csl_loc as f64 / gt_loc as f64;
        ratios.push(ratio);
        table.row(&[
            name.to_string(),
            gt_loc.to_string(),
            spada.to_string(),
            csl_loc.to_string(),
            format!("{ratio:.2}x"),
        ]);
    }

    table.print();
    println!("Harmonic mean expansion: {:.2}x", harmonic_mean(&ratios));
    println!("(paper: 4.68–13.13x for handwritten kernels, up to 616x from GT4Py; HM 14.09x)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn table2_runs() {
        super::run().unwrap();
    }
}

//! Fig. 7 + §VI-D: GEMV runtime vs matrix size — SpaDA 1.5-D
//! A-stationary vs the Cerebras SDK 1-D benchmark (which replicates x/y
//! and goes OOM past 2048²) and the CUBLAS A100 baseline.

use super::common::{run_gemv, run_gemv_variant};
use crate::baselines::{a100, sdk_gemv};
use crate::bench::Table;
use crate::machine::MachineConfig;
use crate::passes::Options;
use crate::runtime::max_rel_err;
use anyhow::Result;

pub fn run(quick: bool) -> Result<()> {
    let g: i64 = if quick { 8 } else { 32 };
    let sizes: &[i64] = if quick { &[64, 256] } else { &[256, 512, 1024, 2048, 4096] };
    let cfg = MachineConfig::with_grid(g, g);
    println!("GEMV y = A·x on a {g}x{g} grid (paper: 1.5-D A-stationary)");
    let mut table = Table::new(&[
        "N", "chain[cyc]", "tree[cyc]", "us(chain)", "SDK-1D[cyc]", "A100[us]", "max rel err",
    ]);
    for &n in sizes {
        let spada = match run_gemv(n, g, &Options::default()) {
            Ok((run, y, want)) => Some((run, max_rel_err(&y, &want))),
            Err(e) if e.to_string().contains("OOM") => None,
            Err(e) => return Err(e),
        };
        let tree = match run_gemv_variant("gemv_tree", n, g, &Options::default()) {
            Ok((run, y, want)) => Some((run, max_rel_err(&y, &want))),
            Err(e) if e.to_string().contains("OOM") => None,
            Err(e) => return Err(e),
        };
        let sdk = sdk_gemv::cycles(n as u64, n as u64);
        let gpu = a100::gemv_runtime_us(n as f64, n as f64);
        table.row(&[
            n.to_string(),
            spada.as_ref().map(|(r, _)| r.report.cycles.to_string()).unwrap_or("OOM".into()),
            tree.as_ref().map(|(r, _)| r.report.cycles.to_string()).unwrap_or("OOM".into()),
            spada
                .as_ref()
                .map(|(r, _)| format!("{:.2}", r.report.runtime_us(&cfg)))
                .unwrap_or("-".into()),
            sdk.map(|c| c.to_string()).unwrap_or("OOM".into()),
            format!("{gpu:.2}"),
            spada
                .as_ref()
                .map(|(_, e)| format!("{e:.1e}"))
                .or(tree.as_ref().map(|(_, e)| format!("{e:.1e}")))
                .unwrap_or("-".into()),
        ]);
    }
    table.print();
    println!(
        "(paper at 2048²: SDK 15,410 cyc vs two-phase 2,822 / direct 5,597 — 5.46x; \
         SDK is OOM for anything larger. Our grid is {g}x{g}, not 750x994, so absolute \
         cycles differ; the SDK-vs-SpaDA ordering and the OOM wall are the claims checked.)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig7_quick() {
        super::run(true).unwrap();
    }
}

//! Fig. 5: 1-D broadcast collectives (512×1 PEs) — SpaDA's single
//! multicast stream vs the handwritten broadcast.

use super::common::run_broadcast;
use crate::baselines::luczynski;
use crate::bench::Table;
use crate::passes::Options;
use anyhow::Result;

pub fn run(quick: bool) -> Result<()> {
    let p: i64 = if quick { 64 } else { 512 };
    let sizes: &[i64] = if quick { &[16, 256] } else { &[1, 4, 16, 64, 256, 1024, 4096] };
    println!("1-D broadcast on {p}x1 PEs (paper: 512x1)");
    let mut table = Table::new(&["K", "bytes", "SpaDA[cyc]", "handwritten", "ratio", "flows"]);
    for &k in sizes {
        let run = run_broadcast(p, k, &Options::default())?;
        let hand = luczynski::broadcast_1d(p as u64, k as u64);
        table.row(&[
            k.to_string(),
            (4 * k).to_string(),
            run.report.cycles.to_string(),
            format!("{hand:.0}"),
            format!("{:.2}x", run.report.cycles as f64 / hand),
            run.report.metrics.flows.to_string(),
        ]);
    }
    table.print();
    println!("(paper: 30%-100% overhead vs handwritten, one DSD op — we also use one multicast flow)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig5_quick() {
        super::run(true).unwrap();
    }
}

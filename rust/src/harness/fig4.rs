//! Fig. 4: 2-D reduce collectives — runtime vs message size, SpaDA
//! generated code vs the handwritten near-optimal kernels (Luczynski et
//! al.), including the tree/two-phase crossover.

use super::common::{run_reduce, harmonic_mean};
use crate::baselines::luczynski;
use crate::bench::Table;
use crate::machine::MachineConfig;
use crate::passes::Options;
use anyhow::Result;

pub fn run(quick: bool) -> Result<()> {
    let g: i64 = if quick { 16 } else { 64 };
    let sizes: &[i64] = if quick { &[16, 256] } else { &[1, 4, 16, 64, 256, 1024, 4096] };
    let cfg = MachineConfig::with_grid(g, g);
    println!("2-D reduce on {g}x{g} PEs (paper: 512x512); message = K f32 words");

    let mut table = Table::new(&[
        "K", "bytes", "tree[cyc]", "hand-tree", "ratio", "2phase[cyc]", "hand-2ph", "ratio",
    ]);
    let mut ratios = vec![];
    for &k in sizes {
        let (tree, _) = run_reduce("tree_reduce", g, g, k, &Options::default())?;
        let (tp, _) = run_reduce("two_phase_reduce", g, g, k, &Options::default())?;
        let hand_tree = luczynski::tree_2d(g as u64, g as u64, k as u64);
        let hand_tp = luczynski::two_phase_2d(g as u64, g as u64, k as u64);
        let rt = tree.report.cycles as f64 / hand_tree;
        let r2 = tp.report.cycles as f64 / hand_tp;
        ratios.push(rt);
        ratios.push(r2);
        table.row(&[
            k.to_string(),
            (4 * k).to_string(),
            tree.report.cycles.to_string(),
            format!("{hand_tree:.0}"),
            format!("{rt:.2}x"),
            tp.report.cycles.to_string(),
            format!("{hand_tp:.0}"),
            format!("{r2:.2}x"),
        ]);
    }
    table.print();
    println!(
        "harmonic-mean slowdown vs handwritten: {:.2}x  (paper: 1.04x)",
        harmonic_mean(&ratios)
    );
    println!(
        "runtime conversion: cycles/0.85 ns; e.g. 1000 cycles = {:.2} us",
        cfg.cycles_to_us(1000)
    );
    println!("crossover check: tree wins small K, two-phase wins large K (shape match)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig4_quick() {
        super::run(true).unwrap();
    }
}

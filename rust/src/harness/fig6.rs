//! Fig. 6: stencil FLOP/s for a fixed horizontal domain and varying
//! vertical levels. Horizontal stencils (Laplacian, UVBKE) scale with K
//! (independent parallel work per level); the vertical stencil's
//! sequential k recurrence runs inside each PE and stops scaling.

use super::common::{extrapolate_floprate, run_stencil, FREQ_HZ};
use crate::baselines::a100;
use crate::bench::{eng, Table};
use crate::machine::MachineConfig;
use crate::passes::Options;
use anyhow::Result;

pub fn run(quick: bool) -> Result<()> {
    let (nx, ny): (i64, i64) = if quick { (8, 8) } else { (32, 32) };
    let levels: &[i64] = if quick { &[4, 16] } else { &[1, 2, 4, 8, 16, 17, 32, 64, 128] };
    let cfg = MachineConfig::with_grid(nx, ny);
    println!(
        "stencils on {nx}x{ny} PEs, varying K (paper: 746x990, K up to 320);\n\
         'wafer' extrapolates the measured rate to 745.5k PEs (per-PE work is scale-invariant)"
    );
    let mut table = Table::new(&["stencil", "K", "cycles", "Gflop/s(sim)", "wafer est", "A100"]);
    for name in ["laplacian", "vertical", "uvbke"] {
        for &k in levels {
            let r = run_stencil(name, nx, ny, k, &Options::default())?;
            let rate = r.run.report.flops_per_sec(&cfg);
            let wafer = extrapolate_floprate(rate, (nx * ny) as f64);
            let (fpp, fields) = match name {
                "laplacian" => (5.0, 2.0),
                "uvbke" => (7.0, 3.0),
                _ => (2.0, 2.0),
            };
            let a100_rate = a100::stencil_floprate(fpp, fields, (746.0 * 990.0) * k as f64);
            table.row(&[
                name.to_string(),
                k.to_string(),
                r.run.report.cycles.to_string(),
                eng(rate),
                eng(wafer),
                eng(a100_rate),
            ]);
        }
    }
    table.print();
    let _ = FREQ_HZ;
    println!(
        "(paper: UVBKE >260 Tflop/s at wafer scale, >400x the A100; the vertical stencil \
         plateaus once the per-column recurrence dominates — same shapes expected above)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_quick() {
        super::run(true).unwrap();
    }
}

//! `spada bench --exp fleet` — batch-engine throughput.
//!
//! Pushes one mixed job list (every library kernel, two grids, repeated
//! seeds so the plan cache has real hits) through [`crate::fleet::run_batch`]
//! at pool widths 1 and 4, and reports whole-simulations-per-second —
//! the service-level figure the per-kernel `--exp sim` sweep cannot
//! see, because it measures one simulator at a time.
//!
//! Rows are written in the `BENCH_sim.json` line format (kernel /
//! grid / threads / events_per_sec, so `spada bench --compare` parses
//! them without special cases) with the fleet-level extras riding
//! along as extra keys: `sims_per_sec`, `jobs`, `compiles`. The
//! committed `BENCH_baseline.json` is never touched.
//!
//! The run doubles as an end-to-end determinism check: the pool-1 and
//! pool-4 row streams must be byte-identical, or the bench aborts.

use crate::bench::{eng, Table};
use crate::fleet::{run_batch, FleetOptions, JobSpec, PlanCache};
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Pool widths measured (mirrors the CI batch smoke legs).
pub const POOLS: &[usize] = &[1, 4];

/// The mixed fleet workload: every kernel × two grids × repeated
/// seeds, plus a finite-buffer and a no-vectorize variant, so the
/// batch exercises cache hits and per-job option isolation, not just
/// cold compiles.
pub fn job_list(quick: bool) -> Vec<JobSpec> {
    let kernels =
        ["chain_reduce", "broadcast", "tree_reduce", "two_phase_reduce", "gemv", "gemv_tree"];
    let grids: &[i64] = if quick { &[4, 8] } else { &[4, 8, 16] };
    let seeds: &[u64] = if quick { &[1, 2] } else { &[1, 2, 3] };
    let mut jobs = Vec::new();
    for &g in grids {
        for kernel in kernels {
            for &seed in seeds {
                jobs.push(JobSpec {
                    id: format!("{kernel}-g{g}-s{seed}"),
                    kernel: kernel.to_string(),
                    g,
                    k: 16,
                    seed,
                    ..JobSpec::default()
                });
            }
        }
    }
    // Option-isolation variants: same shapes, different run options —
    // they must share the cached compilations above.
    jobs.push(JobSpec {
        id: "gemv-capped".into(),
        kernel: "gemv".into(),
        g: grids[0],
        k: 16,
        seed: 1,
        buf_cap: Some(64),
        ..JobSpec::default()
    });
    jobs.push(JobSpec {
        id: "tree-novec".into(),
        kernel: "tree_reduce".into(),
        g: grids[0],
        k: 16,
        seed: 1,
        no_vec: true,
        ..JobSpec::default()
    });
    jobs
}

/// One measured pool width.
pub struct FleetPoint {
    pub pool: usize,
    pub jobs: usize,
    pub compiles: u64,
    pub wall_ms: f64,
    pub sims_per_sec: f64,
    /// Aggregate simulated events processed per host second across the
    /// whole batch — comparable to the `--exp sim` per-run figure.
    pub events_per_sec: f64,
}

/// Run the workload at every pool width. Each width gets a fresh
/// [`PlanCache`], so the measured time always includes the same
/// compile-once work. Returns the points plus the (identical) row
/// stream.
pub fn sweep(quick: bool) -> Result<(Vec<FleetPoint>, Vec<String>)> {
    let jobs = job_list(quick);
    let mut points = Vec::new();
    let mut reference: Option<Vec<String>> = None;
    for &pool in POOLS {
        let cache = PlanCache::new();
        let fleet = FleetOptions { pool, ..FleetOptions::default() };
        let mut rows: Vec<String> = Vec::with_capacity(jobs.len());
        let mut events = 0u64;
        let mut failed: Vec<String> = Vec::new();
        let t0 = Instant::now();
        let summary = run_batch(&jobs, &fleet, &cache, |r| {
            events += r.report.as_ref().map(|m| m.events).unwrap_or(0);
            if let Some((kind, msg)) = &r.error {
                failed.push(format!("{}: {kind}: {msg}", r.id));
            }
            rows.push(r.to_jsonl());
        });
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        if !failed.is_empty() {
            bail!("fleet bench jobs failed at pool {pool}: {}", failed.join("; "));
        }
        match &reference {
            None => reference = Some(rows.clone()),
            Some(want) => {
                if *want != rows {
                    bail!(
                        "fleet determinism violated: pool {pool} rows differ from pool {} rows",
                        POOLS[0]
                    );
                }
            }
        }
        points.push(FleetPoint {
            pool,
            jobs: summary.jobs,
            compiles: summary.compiles,
            wall_ms: wall_s * 1e3,
            sims_per_sec: summary.jobs as f64 / wall_s,
            events_per_sec: events as f64 / wall_s,
        });
    }
    Ok((points, reference.unwrap_or_default()))
}

fn json_of(points: &[FleetPoint], quick: bool) -> String {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"fleet\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"runs\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"fleet_mixed\", \"grid\": \"batch\", \"threads\": {}, \
             \"host_parallelism\": {}, \"jobs\": {}, \"compiles\": {}, \"wall_ms\": {:.3}, \
             \"sims_per_sec\": {:.2}, \"events_per_sec\": {:.1}}}{}\n",
            p.pool,
            host,
            p.jobs,
            p.compiles,
            p.wall_ms,
            p.sims_per_sec,
            p.events_per_sec,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn run(quick: bool) -> Result<()> {
    let (points, _rows) = sweep(quick)?;
    let mut table =
        Table::new(&["pool", "jobs", "compiles", "wall ms", "sims/s", "events/s"]);
    for p in &points {
        table.row(&[
            p.pool.to_string(),
            p.jobs.to_string(),
            p.compiles.to_string(),
            format!("{:.1}", p.wall_ms),
            format!("{:.2}", p.sims_per_sec),
            eng(p.events_per_sec),
        ]);
    }
    table.print();
    println!("rows byte-identical across pool widths {POOLS:?}");
    let out = super::sim_scaling::OUT_FILE;
    std::fs::write(out, json_of(&points, quick)).context(out)?;
    println!("wrote {out} ({} pool widths)", points.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_rows_parse_with_the_bench_gate_parser() {
        let points = vec![FleetPoint {
            pool: 4,
            jobs: 26,
            compiles: 12,
            wall_ms: 100.0,
            sims_per_sec: 260.0,
            events_per_sec: 1.0e6,
        }];
        let json = json_of(&points, true);
        let parsed = super::super::sim_scaling::parse_bench_json(&json).unwrap();
        assert_eq!(parsed.runs.len(), 1);
        assert_eq!(parsed.runs[0].kernel, "fleet_mixed");
        assert_eq!(parsed.runs[0].grid, "batch");
        assert_eq!(parsed.runs[0].threads, 4);
        assert!((parsed.runs[0].events_per_sec.unwrap() - 1.0e6).abs() < 1.0);
    }

    #[test]
    fn quick_job_list_is_mixed() {
        let jobs = job_list(true);
        assert_eq!(jobs.len(), 26);
        // Duplicated shapes guarantee cache hits: 6 kernels × 2 grids
        // distinct shapes, 26 jobs.
        let shapes: std::collections::BTreeSet<(String, i64, i64)> =
            jobs.iter().map(|j| (j.kernel.clone(), j.g, j.k)).collect();
        assert_eq!(shapes.len(), 12);
        assert!(jobs.iter().any(|j| j.buf_cap.is_some()));
        assert!(jobs.iter().any(|j| j.no_vec));
    }
}

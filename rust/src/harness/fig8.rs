//! Fig. 8: roofline + performance-per-Watt.
//!
//! Places every kernel on the WSE-2 roofline (SRAM 8.8 PB/s effective,
//! fabric on/off-ramp 3.3 PB/s, FP32 peak) using measured arithmetic
//! intensities from the simulator's traffic counters, alongside the
//! analytic A100 points, annotated with GFLOP/s/W.

use super::common::{extrapolate_floprate, run_gemv, run_reduce, run_stencil, PAPER_PES};
use crate::baselines::{a100, wse2};
use crate::bench::{eng, Table};
use crate::machine::MachineConfig;
use crate::passes::Options;
use anyhow::Result;

pub fn run(quick: bool) -> Result<()> {
    let (nx, ny): (i64, i64) = if quick { (8, 8) } else { (32, 32) };
    let k = if quick { 8 } else { 64 };
    let cfg = MachineConfig::with_grid(nx, ny);
    let freq = cfg.freq_ghz * 1e9;

    println!("roofline: intensities measured from simulator traffic counters;");
    println!("rates extrapolated to {PAPER_PES} PEs; GF/W at 16.5 kW (WSE-2) / 250 W (A100)");
    let mut table = Table::new(&[
        "kernel", "I_mem[f/B]", "I_ramp[f/B]", "flop/s(wafer)", "roofline", "%roof", "GF/W",
    ]);

    let mut add = |name: &str, report: &crate::machine::RunReport| {
        let rate = extrapolate_floprate(report.flops_per_sec(&cfg), (nx * ny) as f64);
        let im = report.intensity_mem();
        let ir = report.intensity_ramp();
        let bound = wse2::bound_floprate(PAPER_PES, freq, im, ir);
        let gfw = rate / 1e9 / wse2::POWER_LOW_W;
        table.row(&[
            name.to_string(),
            format!("{im:.3}"),
            if ir.is_finite() { format!("{ir:.3}") } else { "inf".into() },
            eng(rate),
            eng(bound),
            format!("{:.0}%", 100.0 * rate / bound),
            format!("{gfw:.2}"),
        ]);
    };

    for name in ["laplacian", "uvbke", "vertical"] {
        let r = run_stencil(name, nx, ny, k, &Options::default())?;
        add(name, &r.run.report);
    }
    {
        let (run, _, _) = run_gemv(if quick { 64 } else { 1024 }, if quick { 8 } else { 32 }, &Options::default())?;
        add("gemv", &run.report);
    }
    {
        let (run, _) = run_reduce("two_phase_reduce", nx, ny, k, &Options::default())?;
        add("two_phase_reduce", &run.report);
    }
    table.print();

    println!("\nA100 baselines (analytic, DRAM-bound):");
    let mut gpu = Table::new(&["kernel", "flop/s", "GF/W"]);
    for (name, fpp, fields) in
        [("laplacian", 5.0, 2.0), ("uvbke", 7.0, 3.0), ("vertical", 2.0, 2.0)]
    {
        let rate = a100::stencil_floprate(fpp, fields, 746.0 * 990.0 * 80.0);
        gpu.row(&[name.to_string(), eng(rate), format!("{:.2}", rate / 1e9 / a100::POWER_W)]);
    }
    let rate = a100::gemv_floprate(16384.0, 16384.0);
    gpu.row(&["gemv".into(), eng(rate), format!("{:.2}", rate / 1e9 / a100::POWER_W)]);
    gpu.print();
    println!("(paper: stencils ramp-bound near 3.3 PB/s; GEMV below roofline — naive dot \
              product; WSE stencils up to 12 GF/W vs A100 ~4 GF/W)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig8_quick() {
        super::run(true).unwrap();
    }
}

//! Bench: regenerates Fig. 7 + §VI-D (GEMV vs SDK 1-D vs A100).
fn main() {
    spada::harness::run("fig7", std::env::args().any(|a| a == "--quick")).unwrap();
}

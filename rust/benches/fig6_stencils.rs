//! Bench: regenerates Fig. 6 (stencil FLOP/s vs vertical levels).
fn main() {
    spada::harness::run("fig6", std::env::args().any(|a| a == "--quick")).unwrap();
}

//! Bench: simulator event-loop throughput (the §Perf L3 sim-side
//! numbers): events/s and wavelet-hops/s on representative workloads.
use spada::bench::{bench_ms, eng, Table};
use spada::harness::common::{run_reduce, run_stencil};
use spada::passes::Options;
use std::time::Instant;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let g = if quick { 16 } else { 64 };
    let mut table = Table::new(&["workload", "events", "wall ms", "events/s", "whops/s"]);

    {
        let t0 = Instant::now();
        let (run, _) = run_reduce("two_phase_reduce", g, g, 1024, &Options::default()).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[
            format!("two_phase {g}x{g} K=1024"),
            run.report.metrics.events.to_string(),
            format!("{:.1}", dt * 1e3),
            eng(run.report.metrics.events as f64 / dt),
            eng(run.report.metrics.wavelet_hops as f64 / dt),
        ]);
    }
    {
        let t0 = Instant::now();
        let r = run_stencil("uvbke", g / 2, g / 2, 64, &Options::default()).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[
            format!("uvbke {0}x{0} K=64", g / 2),
            r.run.report.metrics.events.to_string(),
            format!("{:.1}", dt * 1e3),
            eng(r.run.report.metrics.events as f64 / dt),
            eng(r.run.report.metrics.wavelet_hops as f64 / dt),
        ]);
    }
    // Pure event-loop micro: tiny kernel re-simulated many times.
    {
        let (med, _, _) = bench_ms(1, if quick { 3 } else { 10 }, || {
            run_reduce("tree_reduce", 8, 8, 16, &Options::default()).unwrap();
        });
        table.row(&[
            "tree 8x8 K=16 (compile+sim)".into(),
            "-".into(),
            format!("{med:.1}"),
            "-".into(),
            "-".into(),
        ]);
    }
    table.print();
}

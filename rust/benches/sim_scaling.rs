//! Bench: flat-memory simulator scaling sweep (the six paper kernels,
//! 4×4 → 128×128 grids). Thin wrapper over `harness::sim_scaling` —
//! the same sweep the `spada bench --exp sim` CLI subcommand runs —
//! so `cargo bench --bench sim_scaling` and CI produce the identical
//! `BENCH_sim.json` artifact.
//!
//! Pass `--quick` to stop the sweep at 16×16.

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    spada::harness::sim_scaling::run(quick).unwrap();
}

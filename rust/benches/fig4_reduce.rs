//! Bench: regenerates Fig. 4 (2-D reduce collectives vs handwritten).
fn main() {
    spada::harness::run("fig4", std::env::args().any(|a| a == "--quick")).unwrap();
}

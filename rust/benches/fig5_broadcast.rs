//! Bench: regenerates Fig. 5 (1-D broadcast vs handwritten).
fn main() {
    spada::harness::run("fig5", std::env::args().any(|a| a == "--quick")).unwrap();
}

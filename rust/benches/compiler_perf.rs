//! Bench: compiler pipeline throughput (the §Perf L3 compile-side
//! numbers in EXPERIMENTS.md): parse → instantiate → full pass pipeline
//! for representative kernels.
use spada::bench::{bench_ms, Table};
use spada::kernels;
use spada::machine::MachineConfig;
use spada::passes::Options;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 3 } else { 10 };
    let mut table = Table::new(&["kernel", "grid", "median ms", "min", "max"]);
    let cases: Vec<(&str, Vec<(&str, i64)>, (i64, i64))> = vec![
        ("chain_reduce", vec![("K", 256), ("N", 64)], (64, 1)),
        ("tree_reduce", vec![("K", 256), ("NX", 64), ("NY", 64)], (64, 64)),
        ("two_phase_reduce", vec![("K", 256), ("NX", 64), ("NY", 64)], (64, 64)),
        ("gemv", vec![("M", 1024), ("N", 1024), ("NX", 32), ("NY", 32)], (32, 32)),
    ];
    for (name, binds, (w, h)) in cases {
        let cfg = MachineConfig::with_grid(w, h);
        let (med, lo, hi) = bench_ms(1, iters, || {
            kernels::compile(name, &binds, &cfg, &Options::default()).unwrap();
        });
        table.row(&[
            name.to_string(),
            format!("{w}x{h}"),
            format!("{med:.1}"),
            format!("{lo:.1}"),
            format!("{hi:.1}"),
        ]);
    }
    table.print();
}

//! Bench: regenerates Fig. 9 (compiler pass ablations) and Fig. 8
//! (roofline + GF/W) since both consume the same runs.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    spada::harness::run("fig8", quick).unwrap();
    println!();
    spada::harness::run("fig9", quick).unwrap();
}

//! Offline stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored crate
//! provides the (small) subset of anyhow's API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait. Error chains are stored
//! as flattened strings: `{e}` prints the outermost message, `{e:#}`
//! prints the full `outer: inner: ...` chain, matching anyhow's
//! formatting contract closely enough for CLI output and tests.

use std::fmt;

/// A type-erased error with a chain of context messages.
/// `msgs[0]` is the outermost (most recently attached) message.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    /// The root (innermost) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line.
            write!(f, "{}", self.msgs.join(": "))
        } else {
            f.write_str(self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>` — a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// results whose error converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn context_on_result() {
        let r: Result<()> = Err(io_err()).context("outer");
        let e = r.unwrap_err();
        assert!(format!("{e:#}").contains("outer"));
        assert!(format!("{e:#}").contains("missing file"));
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 42));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {fail}");
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert!(f(true).unwrap_err().to_string().contains("true"));
    }
}

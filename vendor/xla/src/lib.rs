//! Offline stub of the `xla` crate's PJRT surface.
//!
//! This is **not** a PJRT implementation: it mirrors exactly the types
//! and signatures `spada::runtime`'s `pjrt_impl` module uses, so that
//! `cargo build --features pjrt` type-checks the oracle bridge in
//! offline/CI builds instead of letting it bit-rot. Every entry point
//! that would touch a real PJRT client fails at runtime with a clear
//! message; swap this directory for the real vendored `xla` crate to
//! run the numerical oracle.

use std::path::Path;

/// Stub error: formatted with `{:?}` at every call site.
#[derive(Debug)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} unavailable (vendor the real `xla` crate over vendor/xla to \
         enable the PJRT oracle)"
    )))
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails, so no other
/// method is ever reached at runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// Stub XLA computation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer readback")
    }
}

/// Stub literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("reshape")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("tuple decomposition")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("literal readback")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("xla stub"));
    }
}

//! Tour of the communication collectives (paper §VI-B): compiles and
//! simulates chain, tree and two-phase reductions plus the multicast
//! broadcast at several message sizes, printing the latency/bandwidth
//! tradeoff the paper's Fig. 4 plots — tree wins small messages, the
//! pipelined schemes win large ones.
//!
//!     cargo run --release --example collectives_tour

use spada::bench::Table;
use spada::harness::common::{run_broadcast, run_reduce};
use spada::machine::MachineConfig;
use spada::passes::Options;

fn main() -> anyhow::Result<()> {
    let g = 16i64;
    let cfg = MachineConfig::with_grid(g, g);
    println!("reductions on a {g}x{g} grid ({} PEs):\n", g * g);

    let mut table = Table::new(&["K (f32)", "tree[cyc]", "two-phase[cyc]", "winner"]);
    for k in [1i64, 8, 64, 512, 4096] {
        let (tree, _) = run_reduce("tree_reduce", g, g, k, &Options::default())?;
        let (tp, _) = run_reduce("two_phase_reduce", g, g, k, &Options::default())?;
        let (t, p) = (tree.report.cycles, tp.report.cycles);
        table.row(&[
            k.to_string(),
            t.to_string(),
            p.to_string(),
            if t < p { "tree".into() } else { "two-phase".to_string() },
        ]);
    }
    table.print();

    println!("\n1-D collectives on a {g}-PE row:");
    let mut t2 = Table::new(&["K (f32)", "chain[cyc]", "broadcast[cyc]", "bcast flows"]);
    for k in [16i64, 256, 2048] {
        let (chain, _) = run_reduce("chain_reduce", g, 1, k, &Options::default())?;
        let bc = run_broadcast(g, k, &Options::default())?;
        t2.row(&[
            k.to_string(),
            chain.report.cycles.to_string(),
            bc.report.cycles.to_string(),
            bc.report.metrics.flows.to_string(),
        ]);
    }
    t2.print();

    println!(
        "\n(1 cycle = {:.3} ns at 0.85 GHz; broadcast is a single multicast circuit, so \
         its flow count stays 1 regardless of the fan-out)",
        1.0 / cfg.freq_ghz
    );
    Ok(())
}

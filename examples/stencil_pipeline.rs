//! End-to-end driver (DESIGN.md §E2E): the full four-stage pipeline of
//! the paper on a real small workload —
//!
//!   GT4Py-style stencil → Stencil IR → SpaDA → CSL/machine program →
//!   WSE-2 simulation → gather → **PJRT oracle check** (the Layer-2 JAX
//!   model wrapping the Layer-1 Pallas kernel, loaded from
//!   `artifacts/laplacian_16x16x8.hlo.txt`).
//!
//! Reports the paper's headline metric (stencil FLOP/s + wafer-scale
//! estimate). Requires `make artifacts` first.
//!
//!     cargo run --release --example stencil_pipeline

use spada::frontend::{lower_stencil, parse_stencil, stencil_source};
use spada::machine::{MachineConfig, Simulator};
use spada::passes::Options;
use spada::runtime::{max_rel_err, Input, Runtime};
use spada::sem::instantiate;
use spada::util::SplitMix64;
use spada::{csl, spada as lang};

fn main() -> anyhow::Result<()> {
    let (nx, ny, k) = (16i64, 16i64, 8i64);

    // 1. Frontend: GT4Py-style source → Stencil IR.
    let ir = parse_stencil(stencil_source("laplacian").unwrap()).map_err(anyhow::Error::msg)?;
    println!("--- Stencil IR ---\n{ir}");

    // 2. Stencil IR → SpaDA (placement / dataflow / compute passes).
    let sk = lower_stencil(&ir).map_err(anyhow::Error::msg)?;
    let spada_loc = lang::pretty::count_loc(&sk.kernel);

    // 3. SpaDA → CSL + machine program.
    let binds = [("K".to_string(), k), ("NX".to_string(), nx), ("NY".to_string(), ny)].into();
    let prog = instantiate(&sk.kernel, &binds)?;
    let cfg = MachineConfig::with_grid(nx, ny);
    let compiled = csl::compile(&prog, &cfg, &Options::default())?;
    println!(
        "SpaDA {spada_loc} LoC → CSL {} LoC; {} classes, {} colors, streams split {}",
        compiled.csl_loc(),
        compiled.stats.classes,
        compiled.stats.colors_used,
        compiled.stats.streams_split,
    );

    // 4. Simulate on the {nx}x{ny} fabric.
    let mut sim = Simulator::new(cfg.clone(), compiled.machine)?;
    let mut rng = SplitMix64::new(42);
    let input: Vec<f32> = (0..nx * ny * k).map(|_| rng.next_f32()).collect();
    sim.set_input("in_field_ain", &input)?;
    let report = sim.run()?;
    let out = sim.get_output("out_field_aout")?;

    // 5. Oracle: PJRT-executed JAX/Pallas laplacian.
    let rt = Runtime::new(Runtime::default_dir())?;
    let oracle = rt.load(&format!("laplacian_{nx}x{ny}x{k}"))?;
    let want = &oracle.run(&[Input::new(&input, &[nx, ny, k])])?[0];
    let err = max_rel_err(&out, want);
    println!("oracle check: max rel err {err:.2e} over {} elements", out.len());
    assert!(err < 1e-4, "simulation diverges from the JAX/Pallas oracle");

    // 6. Headline metric.
    let rate = report.flops_per_sec(&cfg);
    let wafer = rate * (750.0 * 994.0) / ((nx * ny) as f64);
    println!(
        "laplacian {nx}x{ny}x{k}: {} cycles ({:.2} us), {:.2} Gflop/s simulated, \
         ~{:.1} Tflop/s extrapolated to the 750x994 wafer \
         (paper: 10s-100s of Tflop/s for horizontal stencils)",
        report.cycles,
        report.runtime_us(&cfg),
        rate / 1e9,
        wafer / 1e12
    );
    println!("PE utilization {:.1}%, {} fabric flows, {} wavelets",
        100.0 * report.utilization(), report.metrics.flows, report.metrics.wavelets);
    Ok(())
}

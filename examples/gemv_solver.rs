//! Jacobi iteration driver built on the 1.5-D GEMV kernel: solves
//! A·x = b for a diagonally dominant A by repeatedly launching the
//! compiled GEMV on the simulated wafer — the "domain application"
//! pattern where the WSE kernel is the inner loop of a host solver.
//!
//!     cargo run --release --example gemv_solver

use spada::kernels;
use spada::machine::MachineConfig;
use spada::passes::Options;
use spada::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let (n, g) = (64i64, 4i64);
    let (bm, bn) = ((n / g) as usize, (n / g) as usize);
    let cfg = MachineConfig::with_grid(g, g);

    // Diagonally dominant system.
    let mut rng = SplitMix64::new(7);
    let nn = n as usize;
    let mut a = vec![0f32; nn * nn];
    for r in 0..nn {
        for c in 0..nn {
            a[r * nn + c] = if r == c { nn as f32 } else { 0.3 * rng.next_f32() };
        }
    }
    let x_true: Vec<f32> = (0..nn).map(|i| (i % 5) as f32 - 2.0).collect();
    let b: Vec<f32> = (0..nn)
        .map(|r| (0..nn).map(|c| a[r * nn + c] * x_true[c]).sum())
        .collect();

    // Jacobi: x' = x + D^-1 (b - A x). We compute r = b - A·x on the
    // wafer (alpha=-1, beta=1 with y=b) and update on the host.
    let diag: Vec<f32> = (0..nn).map(|r| a[r * nn + r]).collect();
    let blocks = to_blocks(&a, n, g, bm, bn);
    let mut x = vec![0f32; nn];
    let mut total_cycles = 0u64;
    for iter in 0..25 {
        // One kernel launch = one compiled program instance.
        let ck = kernels::compile(
            "gemv",
            &[("M", n), ("N", n), ("NX", g), ("NY", g)],
            &cfg,
            &Options::default(),
        )?;
        let mut sim = ck.simulator()?;
        sim.set_input("a_blk", &blocks)?;
        sim.set_input("x_in", &x)?;
        sim.set_input("y_in", &b)?;
        sim.set_input("alpha", &[-1.0])?;
        sim.set_input("beta", &[1.0])?;
        let report = sim.run()?;
        total_cycles += report.cycles;
        let r = sim.get_output("y_out")?; // r = b - A x

        let res_norm = (r.iter().map(|v| (v * v) as f64).sum::<f64>()).sqrt();
        for i in 0..nn {
            x[i] += r[i] / diag[i];
        }
        if iter % 5 == 0 || res_norm < 1e-3 {
            println!("iter {iter:2}: |r| = {res_norm:.3e}");
        }
        if res_norm < 1e-3 {
            break;
        }
    }
    let err = x
        .iter()
        .zip(&x_true)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "converged: max |x - x*| = {err:.2e}; {} total device cycles ({:.2} us)",
        total_cycles,
        cfg.cycles_to_us(total_cycles)
    );
    assert!(err < 1e-2);
    Ok(())
}

/// Pack a row-major dense matrix into the kernel's column-major blocks,
/// ports ordered i·NY + j.
fn to_blocks(a: &[f32], n: i64, g: i64, bm: usize, bn: usize) -> Vec<f32> {
    let nn = n as usize;
    let mut blocks = vec![0f32; nn * nn];
    let mut off = 0usize;
    for i in 0..g {
        for j in 0..g {
            for c in 0..bn {
                for r in 0..bm {
                    let gr = j as usize * bm + r;
                    let gc = i as usize * bn + c;
                    blocks[off + c * bm + r] = a[gr * nn + gc];
                }
            }
            off += bm * bn;
        }
    }
    blocks
}

//! Quickstart: compile a SpaDA kernel from source text and simulate it.
//!
//! Shows the whole public API in ~60 lines: parse → instantiate →
//! compile (checkerboard routing, task graph, vectorization) → load into
//! the WSE-2 simulator → run → read results + cycle counts.
//!
//!     cargo run --release --example quickstart

use spada::csl;
use spada::machine::{MachineConfig, Simulator};
use spada::passes::Options;
use spada::sem::instantiate;
use spada::spada::parse_kernel;

fn main() -> anyhow::Result<()> {
    // A 4-PE pipeline that doubles a vector and forwards it east.
    let src = r#"
kernel @relay<K, N>(stream<f32>[1] readonly v_in, stream<f32>[1] writeonly v_out) {
  place i16 i, i16 j in [0:N, 0] { f32[K] buf }
  phase {
    compute i32 i, i32 j in [0, 0] { await receive(buf, v_in[0]) }
  }
  phase {
    dataflow i32 i, i32 j in [0:N, 0] {
      stream<f32> fwd = relative_stream(1, 0)
    }
    // Block order defines per-PE statement order: middle PEs must
    // receive before they double and forward.
    compute i32 i, i32 j in [1:N, 0] {
      await receive(buf, fwd)
    }
    compute i32 i, i32 j in [0:N-1, 0] {
      map i32 k in [0:K] { buf[k] = 2.0 * buf[k] }
      await send(buf, fwd)
    }
  }
  phase {
    compute i32 i, i32 j in [N-1, 0] {
      map i32 k in [0:K] { buf[k] = 2.0 * buf[k] }
      await send(buf, v_out[0])
    }
  }
}
"#;
    // Hmm: each hop doubles before sending, so PE N-1 receives the value
    // doubled N-1 times and doubles once more: out = in * 2^N.
    let (k, n) = (16i64, 4i64);
    let kernel = parse_kernel(src)?;
    let prog = instantiate(&kernel, &[("K".to_string(), k), ("N".to_string(), n)].into())?;
    let cfg = MachineConfig::with_grid(n, 1);
    let compiled = csl::compile(&prog, &cfg, &Options::default())?;
    println!(
        "compiled: {} PE classes, {} colors, {} logical tasks, {} lines of CSL",
        compiled.stats.classes,
        compiled.stats.colors_used,
        compiled.stats.logical_tasks,
        compiled.csl_loc()
    );

    let mut sim = Simulator::new(cfg.clone(), compiled.machine)?;
    let input: Vec<f32> = (0..k).map(|i| i as f32).collect();
    sim.set_input("v_in", &input)?;
    let report = sim.run()?;
    let out = sim.get_output("v_out")?;

    let scale = 2f32.powi(n as i32);
    for (i, (o, inp)) in out.iter().zip(&input).enumerate() {
        assert_eq!(*o, inp * scale, "element {i}");
    }
    println!(
        "relay over {n} PEs: out = in * 2^{n} verified; {} cycles = {:.2} us at 0.85 GHz",
        report.cycles,
        report.runtime_us(&cfg)
    );
    Ok(())
}

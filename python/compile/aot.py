"""AOT lowering: JAX models → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Shapes are fixed here and mirrored by the Rust examples/harness — the
artifact name encodes them (e.g. laplacian_16x16x8.hlo.txt).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shapes shared with the Rust examples (examples/*.rs read these names).
STENCIL_SHAPE = (16, 16, 8)  # (NX, NY, K)
VERTICAL_SHAPE = (8, 8, 16)
GEMV_SHAPE = (64, 48)  # (M, N)
REDUCE_SHAPE = (16, 64)  # (P, K)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifacts():
    nx, ny, k = STENCIL_SHAPE
    vx, vy, vk = VERTICAL_SHAPE
    m, n = GEMV_SHAPE
    p, rk = REDUCE_SHAPE
    return {
        f"laplacian_{nx}x{ny}x{k}": (model.laplacian_model, [f32(nx, ny, k)]),
        f"vertical_{vx}x{vy}x{vk}": (model.vertical_model, [f32(vx, vy, vk)]),
        f"uvbke_{nx}x{ny}x{k}": (model.uvbke_model, [f32(nx, ny, k), f32(nx, ny, k)]),
        f"gemv_{m}x{n}": (
            model.gemv_model,
            [f32(m, n), f32(n), f32(m), f32(), f32()],
        ),
        f"reduce_{p}x{rk}": (model.reduce_model, [f32(p, rk)]),
        f"broadcast_{p}x{rk}": (
            functools.partial(model.broadcast_model, p=p),
            [f32(rk)],
        ),
    }


def emit_gt4py_stencils(out_dir):
    """Demonstrate the Python front half of the paper's pipeline: author
    stencils in the GT4Py-style embedded DSL, emit the textual stencil
    DSL the Rust Stencil-IR frontend consumes
    (`spada compile-stencil <file.gt>`)."""
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from gt4py_like import stencil, Field3D, computation, interval, PARALLEL

    @stencil
    def laplace(in_field: Field3D, out_field: Field3D):
        with computation(PARALLEL), interval(...):
            out_field = -4.0 * in_field[0, 0, 0] + (
                in_field[1, 0, 0] + in_field[-1, 0, 0] +
                in_field[0, 1, 0] + in_field[0, -1, 0])

    sdir = os.path.join(out_dir, "stencils")
    os.makedirs(sdir, exist_ok=True)
    path = laplace.save(os.path.join(sdir, "laplace_from_python.gt"))
    print(f"wrote {path} (GT4Py {laplace.py_loc} LoC -> stencil DSL)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, specs) in artifacts().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    emit_gt4py_stencils(args.out_dir)


if __name__ == "__main__":
    main()

"""Layer-2 JAX compute graphs for every evaluated kernel.

Each model calls the Layer-1 Pallas kernel for its hot loop and adds the
surrounding computation (GEMV's alpha/beta update, etc.). `aot.py`
lowers these once to HLO text; the Rust runtime loads and executes them
as the numerical oracle for simulator outputs.
"""

import jax.numpy as jnp

from .kernels import linalg_pallas, stencils_pallas


def laplacian_model(in_field):
    return (stencils_pallas.laplacian_pallas(in_field),)


def vertical_model(in_field):
    return (stencils_pallas.vertical_pallas(in_field),)


def uvbke_model(u, v):
    return (stencils_pallas.uvbke_pallas(u, v),)


def gemv_model(a, x, y, alpha, beta):
    """y_out = alpha * (A @ x) + beta * y, with the matvec in Pallas."""
    ax = linalg_pallas.gemv_pallas(a, x)
    return (alpha * ax + beta * y,)


def reduce_model(vectors):
    return (linalg_pallas.reduce_pallas(vectors),)


def broadcast_model(vector, p: int):
    """Broadcast is pure data movement; the model just replicates."""
    return (jnp.broadcast_to(vector, (p, vector.shape[0])),)

"""Layer-1 Pallas kernels for GEMV and the reduction collective.

TPU mapping (DESIGN.md §Hardware-Adaptation): the 1.5-D A-stationary
GEMV of the paper keeps matrix blocks resident in PE SRAM and streams
x/partials over the fabric; here A tiles stay VMEM-resident
(MXU-friendly multiples of 8x128 where shapes allow), the grid runs over
(row-tile, col-tile), and the col-tile loop accumulates into the output
block — the same broadcast-multiply-reduce structure.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemv_kernel(a_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ x_ref[...]


def gemv_pallas(a, x, bm=None, bn=None):
    """Blocked y = A @ x over tiles of (bm, bn)."""
    m, n = a.shape
    bm = bm or min(m, 128)
    bn = bn or min(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _gemv_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(a, x)


def _reduce_kernel(x_ref, o_ref):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...][0]


def reduce_pallas(vectors):
    """Elementwise sum of P K-vectors, accumulated block by block —
    the chain-reduce dataflow with the fabric hop replaced by grid-step
    revisiting of the output block."""
    p, k = vectors.shape
    return pl.pallas_call(
        _reduce_kernel,
        grid=(p,),
        in_specs=[pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(vectors)

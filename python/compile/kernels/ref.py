"""Pure-jnp reference oracles for every evaluated kernel.

These define the ground-truth numerics the Pallas kernels (and, via the
PJRT bridge, the WSE-2 simulator outputs) are checked against.

Array conventions match the Rust harness:
- stencil fields are (NX, NY, K) -- PE (x, y) owns column [x, y, :];
- GEMV uses a dense (M, N) matrix;
- reductions take (P, K): P per-PE vectors of length K.
"""

import jax.numpy as jnp


def laplacian(in_field):
    """2-D 5-point Laplacian on the horizontal plane, zero boundary.

    out = -4*in + in[+1,0] + in[-1,0] + in[0,+1] + in[0,-1] (interior).
    """
    out = (
        -4.0 * in_field[1:-1, 1:-1, :]
        + in_field[2:, 1:-1, :]
        + in_field[:-2, 1:-1, :]
        + in_field[1:-1, 2:, :]
        + in_field[1:-1, :-2, :]
    )
    return jnp.pad(out, ((1, 1), (1, 1), (0, 0)))


def vertical(in_field):
    """The paper's vertical difference stencil.

    Region 1 (PARALLEL, interval(0, -1)): out[k] = in[k+1] - in[k]
    Region 2 (FORWARD, interval(1, 0)):  out[k] = out[k-1] + in[k]
    """
    out = jnp.zeros_like(in_field)
    out = out.at[:, :, :-1].set(in_field[:, :, 1:] - in_field[:, :, :-1])
    # Sequential prefix along k: out[k] = out[0] + cumsum(in[1..k]).
    csum = jnp.cumsum(in_field[:, :, 1:], axis=2)
    out = out.at[:, :, 1:].set(out[:, :, :1] + csum)
    return out


def uvbke(u, v):
    """COSMO UVBKE kinetic-energy term (interior at x>=1, y>=1)."""
    ua = u[1:, 1:, :] + u[:-1, 1:, :]
    va = v[1:, 1:, :] + v[1:, :-1, :]
    out = 0.125 * (ua * ua + va * va)
    return jnp.pad(out, ((1, 0), (1, 0), (0, 0)))


def gemv(a, x, y, alpha, beta):
    """y_out = alpha * A @ x + beta * y."""
    return alpha * (a @ x) + beta * y


def reduce_sum(vectors):
    """Elementwise sum of P vectors: (P, K) -> (K,)."""
    return jnp.sum(vectors, axis=0)


def broadcast(vector, p):
    """Replicate a K-vector to all P PEs: (K,) -> (P, K)."""
    return jnp.broadcast_to(vector, (p, vector.shape[0]))

"""Layer-1 Pallas stencil kernels (interpret=True for CPU validation).

TPU mapping of the paper's WSE insight (DESIGN.md §Hardware-Adaptation):
the WSE distributes an (NX, NY) plane over PEs with 48 KB SRAM each and
streams halos over the fabric; on TPU the same dataflow becomes VMEM
blocking — one vertical level's full horizontal plane is a block
(746x990 f32 = 2.95 MB, comfortably VMEM-resident), the grid runs over
the K independent levels, and halo accesses are in-block shifts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _laplacian_kernel(in_ref, out_ref):
    x = in_ref[...][:, :, 0]
    core = (
        -4.0 * x[1:-1, 1:-1]
        + x[2:, 1:-1]
        + x[:-2, 1:-1]
        + x[1:-1, 2:]
        + x[1:-1, :-2]
    )
    out_ref[...] = jnp.pad(core, ((1, 1), (1, 1)))[:, :, None]


def laplacian_pallas(in_field):
    """2-D Laplacian over an (NX, NY, K) field; grid over K levels."""
    nx, ny, k = in_field.shape
    return pl.pallas_call(
        _laplacian_kernel,
        grid=(k,),
        in_specs=[pl.BlockSpec((nx, ny, 1), lambda kk: (0, 0, kk))],
        out_specs=pl.BlockSpec((nx, ny, 1), lambda kk: (0, 0, kk)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, k), jnp.float32),
        interpret=True,
    )(in_field)


def _uvbke_kernel(u_ref, v_ref, out_ref):
    u = u_ref[...][:, :, 0]
    v = v_ref[...][:, :, 0]
    ua = u[1:, 1:] + u[:-1, 1:]
    va = v[1:, 1:] + v[1:, :-1]
    core = 0.125 * (ua * ua + va * va)
    out_ref[...] = jnp.pad(core, ((1, 0), (1, 0)))[:, :, None]


def uvbke_pallas(u, v):
    """UVBKE kinetic-energy stencil over (NX, NY, K) wind fields."""
    nx, ny, k = u.shape
    return pl.pallas_call(
        _uvbke_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((nx, ny, 1), lambda kk: (0, 0, kk)),
            pl.BlockSpec((nx, ny, 1), lambda kk: (0, 0, kk)),
        ],
        out_specs=pl.BlockSpec((nx, ny, 1), lambda kk: (0, 0, kk)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, k), jnp.float32),
        interpret=True,
    )(u, v)


def _vertical_kernel(in_ref, out_ref):
    """Whole-column kernel: the k recurrence is sequential per column, so
    the block is a full (1, NY, K) pencil and the grid runs over NX."""
    x = in_ref[...][0]  # (NY, K)
    diff = jnp.zeros_like(x)
    diff = diff.at[:, :-1].set(x[:, 1:] - x[:, :-1])
    csum = jnp.cumsum(x[:, 1:], axis=1)
    out = diff.at[:, 1:].set(diff[:, :1] + csum)
    out_ref[...] = out[None]


def vertical_pallas(in_field):
    """Vertical difference stencil over (NX, NY, K)."""
    nx, ny, k = in_field.shape
    return pl.pallas_call(
        _vertical_kernel,
        grid=(nx,),
        in_specs=[pl.BlockSpec((1, ny, k), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, ny, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, k), jnp.float32),
        interpret=True,
    )(in_field)

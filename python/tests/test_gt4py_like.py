"""gt4py_like frontend: GT4Py-style Python stencils → stencil-DSL text."""

from gt4py_like import stencil, Field3D, computation, interval, PARALLEL, FORWARD


@stencil
def laplace(in_field: Field3D, out_field: Field3D):
    with computation(PARALLEL), interval(...):
        out_field = -4.0 * in_field[0, 0, 0] + (
            in_field[1, 0, 0] + in_field[-1, 0, 0] +
            in_field[0, 1, 0] + in_field[0, -1, 0])


@stencil
def vertical_diff(in_field: Field3D, out_field: Field3D):
    with computation(PARALLEL), interval(0, -1):
        out_field = in_field[0, 0, 1] - in_field[0, 0, 0]
    with computation(FORWARD), interval(1, 0):
        out_field = out_field[0, 0, -1] + in_field[0, 0, 0]


def test_laplace_emits_dsl():
    t = laplace.text
    assert t.startswith("stencil laplace(f32 in_field, f32 out_field) {")
    assert "computation(PARALLEL) interval(0, 0) {" in t
    assert "in_field[1, 0, 0]" in t
    assert "in_field[0, -1, 0]" in t
    assert t.rstrip().endswith("}")


def test_laplace_py_loc_is_small():
    # The Table II "GT4Py" column: a handful of lines.
    assert laplace.py_loc <= 8


def test_vertical_two_regions():
    t = vertical_diff.text
    assert "computation(PARALLEL) interval(0, -1)" in t
    assert "computation(FORWARD) interval(1, 0)" in t
    assert "out_field[0, 0, -1]" in t


def test_roundtrip_against_rust_sources():
    """The emitted DSL must match the embedded Rust-side stencil source
    structurally (same accesses, same regions)."""
    import os
    here = os.path.dirname(__file__)
    rust_src = open(
        os.path.join(here, "..", "..", "rust", "src", "frontend", "stencils", "laplacian.gt")
    ).read()
    for token in ["in_field[1, 0, 0]", "in_field[-1, 0, 0]",
                  "in_field[0, 1, 0]", "in_field[0, -1, 0]"]:
        assert token in rust_src
        assert token in laplace.text

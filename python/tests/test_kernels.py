"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes; fixed-seed numpy data keeps runs deterministic.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.linalg_pallas import gemv_pallas, reduce_pallas
from compile.kernels.stencils_pallas import (
    laplacian_pallas,
    uvbke_pallas,
    vertical_pallas,
)

RNG = np.random.default_rng(0)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def assert_close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------- stencils

@settings(max_examples=10, deadline=None)
@given(
    nx=st.integers(3, 12),
    ny=st.integers(3, 12),
    k=st.integers(1, 6),
)
def test_laplacian_matches_ref(nx, ny, k):
    x = rand(nx, ny, k)
    assert_close(laplacian_pallas(x), ref.laplacian(x))


@settings(max_examples=10, deadline=None)
@given(
    nx=st.integers(2, 10),
    ny=st.integers(2, 10),
    k=st.integers(1, 6),
)
def test_uvbke_matches_ref(nx, ny, k):
    u, v = rand(nx, ny, k), rand(nx, ny, k)
    assert_close(uvbke_pallas(u, v), ref.uvbke(u, v))


@settings(max_examples=10, deadline=None)
@given(
    nx=st.integers(1, 6),
    ny=st.integers(1, 6),
    k=st.integers(2, 20),
)
def test_vertical_matches_ref(nx, ny, k):
    x = rand(nx, ny, k)
    assert_close(vertical_pallas(x), ref.vertical(x), tol=1e-4)


def test_laplacian_zero_boundary():
    out = np.asarray(laplacian_pallas(rand(8, 8, 3)))
    assert np.all(out[0] == 0) and np.all(out[-1] == 0)
    assert np.all(out[:, 0] == 0) and np.all(out[:, -1] == 0)


# ---------------------------------------------------------------- linalg

@settings(max_examples=10, deadline=None)
@given(
    mt=st.integers(1, 4),
    nt=st.integers(1, 4),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
)
def test_gemv_matches_ref(mt, nt, bm, bn):
    m, n = mt * bm, nt * bn
    a, x = rand(m, n), rand(n)
    assert_close(gemv_pallas(a, x, bm=bm, bn=bn), a @ x, tol=1e-4)


def test_gemv_rejects_ragged_tiles():
    with pytest.raises(AssertionError):
        gemv_pallas(rand(10, 10), rand(10), bm=3, bn=3)


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 32), k=st.integers(1, 64))
def test_reduce_matches_ref(p, k):
    v = rand(p, k)
    assert_close(reduce_pallas(v), ref.reduce_sum(v), tol=1e-4)


def test_gemv_model_alpha_beta():
    from compile.model import gemv_model

    a, x, y = rand(32, 16), rand(16), rand(32)
    (got,) = gemv_model(a, x, y, np.float32(2.0), np.float32(0.5))
    assert_close(got, ref.gemv(a, x, y, 2.0, 0.5), tol=1e-4)

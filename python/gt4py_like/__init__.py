"""A miniature GT4Py-style embedded stencil DSL (paper §IV front half).

Stencil functions are written in GT4Py's idiom::

    @stencil
    def laplace(in_field: Field3D, out_field: Field3D):
        with computation(PARALLEL), interval(...):
            out_field = -4.0 * in_field[0, 0, 0] + (
                in_field[1, 0, 0] + in_field[-1, 0, 0] +
                in_field[0, 1, 0] + in_field[0, -1, 0])

The decorator AST-parses the function (exactly how real GT4Py ingests
stencils) and emits the textual stencil-DSL consumed by the Rust
Stencil-IR frontend (`spada compile --stencil`). This keeps the paper's
GT4Py → Stencil IR → SpaDA pipeline shape: Python authors stencils at
build time, Rust owns everything from the IR down.
"""

import ast
import inspect
import textwrap

__all__ = [
    "stencil",
    "Field3D",
    "computation",
    "interval",
    "PARALLEL",
    "FORWARD",
    "BACKWARD",
]


class Field3D:
    """Type annotation marker for 3-D (I, J, K) fields."""


PARALLEL = "PARALLEL"
FORWARD = "FORWARD"
BACKWARD = "BACKWARD"


def computation(order):  # pragma: no cover - marker only
    raise RuntimeError("computation() is only valid inside @stencil functions")


def interval(*bounds):  # pragma: no cover - marker only
    raise RuntimeError("interval() is only valid inside @stencil functions")


class StencilDef:
    """The result of @stencil: holds the emitted stencil-DSL text."""

    def __init__(self, name, fields, text, py_loc):
        self.name = name
        self.fields = fields
        self.text = text
        #: lines of the original GT4Py-style definition (Table II column).
        self.py_loc = py_loc

    def save(self, path):
        with open(path, "w") as f:
            f.write(self.text)
        return path


def _expr(node) -> str:
    if isinstance(node, ast.BinOp):
        op = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/"}[type(node.op)]
        return f"({_expr(node.left)} {op} {_expr(node.right)})"
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return f"-{_expr(node.operand)}"
    if isinstance(node, ast.Constant):
        return repr(float(node.value))
    if isinstance(node, ast.Subscript):
        field = node.value.id
        idx = node.slice
        offs = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        vals = []
        for o in offs:
            if isinstance(o, ast.Constant):
                vals.append(int(o.value))
            elif isinstance(o, ast.UnaryOp) and isinstance(o.op, ast.USub):
                vals.append(-int(o.operand.value))
            else:
                raise ValueError(f"non-constant stencil offset: {ast.dump(o)}")
        if len(vals) != 3:
            raise ValueError("stencil accesses need 3 offsets [di, dj, dk]")
        return f"{field}[{vals[0]}, {vals[1]}, {vals[2]}]"
    if isinstance(node, ast.Name):
        # Bare field name = zero-offset access (GT4Py allows both).
        return f"{node.id}[0, 0, 0]"
    raise ValueError(f"unsupported stencil expression: {ast.dump(node)}")


def _region_header(withitem) -> str:
    """Translate `computation(X), interval(a, b)` with-items."""
    call = withitem.context_expr
    if not isinstance(call, ast.Call):
        raise ValueError("with items must be computation()/interval() calls")
    fname = call.func.id
    if fname == "computation":
        order = call.args[0].id if isinstance(call.args[0], ast.Name) else call.args[0].value
        return f"computation({order})"
    if fname == "interval":
        # interval(...) (Ellipsis) → full domain.
        if len(call.args) == 1 and isinstance(call.args[0], ast.Constant) \
                and call.args[0].value is Ellipsis:
            return "interval(0, 0)"
        vals = []
        for a in call.args:
            if isinstance(a, ast.Constant) and a.value is None:
                vals.append(0)
            elif isinstance(a, ast.Constant):
                vals.append(int(a.value))
            elif isinstance(a, ast.UnaryOp) and isinstance(a.op, ast.USub):
                vals.append(-int(a.operand.value))
            else:
                raise ValueError("interval bounds must be constants")
        if len(vals) != 2:
            raise ValueError("interval() needs two bounds (or ...)")
        return f"interval({vals[0]}, {vals[1]})"
    raise ValueError(f"unknown with-item {fname}")


def stencil(fn):
    """Decorator: parse a GT4Py-style function into stencil-DSL text."""
    src = textwrap.dedent(inspect.getsource(fn))
    tree = ast.parse(src)
    fdef = tree.body[0]
    assert isinstance(fdef, ast.FunctionDef)
    fields = [a.arg for a in fdef.args.args]

    lines = [f"stencil {fdef.name}({', '.join(f'f32 {f}' for f in fields)}) {{"]
    for node in fdef.body:
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Constant):
            continue  # docstring
        if not isinstance(node, ast.With):
            raise ValueError("stencil bodies are `with computation(...)` blocks")
        headers = [_region_header(w) for w in node.items]
        comp = next((h for h in headers if h.startswith("computation")), None)
        intv = next((h for h in headers if h.startswith("interval")), "interval(0, 0)")
        if comp is None:
            raise ValueError("missing computation(...) in with block")
        lines.append(f"  {comp} {intv} {{")
        for stmt in node.body:
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                raise ValueError("stencil statements must be single assignments")
            target = stmt.targets[0]
            tname = target.id if isinstance(target, ast.Name) else target.value.id
            lines.append(f"    {tname} = {_expr(stmt.value)}")
        lines.append("  }")
    lines.append("}")

    py_loc = len([l for l in src.splitlines() if l.strip() and not l.strip().startswith("#")])
    return StencilDef(fdef.name, fields, "\n".join(lines) + "\n", py_loc)
